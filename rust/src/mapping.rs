//! ILP-based model-to-accelerator mapping (paper §III-D) and the
//! *distiller* that turns solutions into controller memory images.
//!
//! The paper assigns every destination-layer neuron `i` to a capacitor `k`
//! of an A-NEURON `j` via binaries `x_{i,j,k}` (eq. 3) minimizing
//! unassigned neurons (eq. 4) under engine capacity (eq. 5), unique
//! assignment (eq. 6) and source fan-out (eq. 7). When a layer has more
//! neurons than the M·N capacitors, the controller processes the layer in
//! **rounds**, reassigning capacitors once a neuron's connections are
//! processed ("the capacitor tied to that neuron must be reassigned") —
//! so the full mapping is a sequence of per-round assignments.
//!
//! Solver strategies:
//! * [`Strategy::IlpExact`] — the literal eqs. (3)–(7) ILP via the in-tree
//!   branch & bound. Provably optimal; practical for small layers and used
//!   to certify the fast path.
//! * [`Strategy::IlpFlow`] — the production path. Capacitors within one
//!   A-NEURON are interchangeable, so collapsing `k` yields a
//!   transportation problem (totally unimodular ⇒ LP = ILP optimum),
//!   solved as min-cost max-flow with convex per-engine costs that also
//!   balance neurons across engines. A weighted-load local-refinement
//!   pass then balances expected *event* load (communication overhead,
//!   §III-D).
//! * [`Strategy::Greedy`] / [`Strategy::FirstFit`] / [`Strategy::RoundRobin`]
//!   — baselines for the mapping ablation (DESIGN.md X2).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::AcceleratorConfig;
use crate::ilp::branch_bound::{self, BnbConfig};
use crate::ilp::mcmf::McmfGraph;
use crate::ilp::{Cmp, Problem, Status};
use crate::snn::{ConvSpec, QuantLayer, QuantNetwork};

/// Mapping strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    IlpExact,
    IlpFlow,
    Greedy,
    FirstFit,
    RoundRobin,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::IlpExact => "ilp_exact",
            Strategy::IlpFlow => "ilp_flow",
            Strategy::Greedy => "greedy",
            Strategy::FirstFit => "first_fit",
            Strategy::RoundRobin => "round_robin",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "ilp_exact" => Strategy::IlpExact,
            "ilp_flow" | "ilp" => Strategy::IlpFlow,
            "greedy" => Strategy::Greedy,
            "first_fit" => Strategy::FirstFit,
            "round_robin" => Strategy::RoundRobin,
            _ => bail!("unknown mapping strategy {s:?}"),
        })
    }

    pub fn all() -> [Strategy; 5] {
        [
            Strategy::IlpExact,
            Strategy::IlpFlow,
            Strategy::Greedy,
            Strategy::FirstFit,
            Strategy::RoundRobin,
        ]
    }
}

/// A slot is one capacitor of one A-NEURON.
pub type Slot = (u16, u16); // (engine j, capacitor k)

/// Assignment of destination neurons to slots for one round.
#[derive(Debug, Clone, Default)]
pub struct RoundAssignment {
    /// `slot_of[i]` for each destination neuron handled this round.
    pub slot_of: BTreeMap<u32, Slot>,
}

impl RoundAssignment {
    /// Per-engine neuron counts.
    pub fn engine_counts(&self, m: usize) -> Vec<usize> {
        let mut c = vec![0usize; m];
        for &(j, _) in self.slot_of.values() {
            c[j as usize] += 1;
        }
        c
    }
}

/// Complete mapping of one layer onto one MX-NEURACORE.
#[derive(Debug, Clone)]
pub struct LayerMapping {
    pub rounds: Vec<RoundAssignment>,
    /// Destination neurons that could not be assigned in any round
    /// (objective (4) — empty whenever rounds are allowed and fan-out
    /// limits are satisfiable).
    pub unassigned: Vec<u32>,
    /// Strategy that produced this mapping.
    pub strategy: Strategy,
    /// ILP nodes explored (exact path) — solver effort metric.
    pub solver_nodes: usize,
}

impl LayerMapping {
    /// Check the paper's constraints (5)–(7) hold for every round.
    ///
    /// Conv layers (compressed or their expansion oracle) use the fixed
    /// canonical layout instead and are checked against it — see
    /// [`Self::validate_conv`].
    pub fn validate(&self, layer: &QuantLayer, cfg: &AcceleratorConfig) -> Result<()> {
        if layer.conv.is_some() {
            return self.validate_conv(layer, cfg);
        }
        let m = cfg.a_neurons_per_core;
        let n = cfg.virtual_per_a_neuron;
        let mut seen = vec![false; layer.out_dim];
        for (ri, round) in self.rounds.iter().enumerate() {
            let mut slot_used: BTreeMap<Slot, u32> = BTreeMap::new();
            let mut engine_load = vec![0usize; m];
            for (&i, &(j, k)) in &round.slot_of {
                if i as usize >= layer.out_dim {
                    bail!("round {ri}: neuron {i} out of range");
                }
                if j as usize >= m || k as usize >= n {
                    bail!("round {ri}: slot ({j},{k}) out of range");
                }
                // Unique assignment across the whole mapping (eq. 6).
                if seen[i as usize] {
                    bail!("neuron {i} assigned twice");
                }
                seen[i as usize] = true;
                // One neuron per capacitor per round.
                if let Some(prev) = slot_used.insert((j, k), i) {
                    bail!("round {ri}: slot ({j},{k}) holds {prev} and {i}");
                }
                engine_load[j as usize] += 1;
            }
            // Engine capacity (eq. 5).
            for (j, &load) in engine_load.iter().enumerate() {
                if load > n {
                    bail!("round {ri}: engine {j} overloaded ({load} > {n})");
                }
            }
            // Fan-out (eq. 7): connections from each source to this round's
            // assigned neurons must respect the limit.
            let mut fanout = vec![0usize; layer.in_dim];
            for s in 0..layer.in_dim {
                for &(d, _) in layer.targets_of(s) {
                    if round.slot_of.contains_key(&d) {
                        fanout[s] += 1;
                    }
                }
            }
            if let Some((s, &f)) =
                fanout.iter().enumerate().find(|(_, &f)| f > cfg.fanout_limit)
            {
                bail!("round {ri}: source {s} fan-out {f} exceeds limit {}", cfg.fanout_limit);
            }
        }
        // Completeness: every neuron with incoming connections must be
        // assigned or explicitly reported unassigned.
        let mut has_input = vec![false; layer.out_dim];
        for s in 0..layer.in_dim {
            for &(d, _) in layer.targets_of(s) {
                has_input[d as usize] = true;
            }
        }
        for (i, (&s, &h)) in seen.iter().zip(&has_input).enumerate() {
            let listed = self.unassigned.contains(&(i as u32));
            if h && !s && !listed {
                bail!("neuron {i} has inputs but is neither assigned nor reported unassigned");
            }
        }
        Ok(())
    }

    /// Check a conv layer's mapping is exactly the canonical layout of
    /// [`map_conv_canonical`]: destination `d` lives in round `d/(M·N)` at
    /// slot `(pos/N, pos%N)` with `pos = d mod M·N`, every destination
    /// assigned (dead ones included — the generator must find its targets
    /// at arithmetically determined slots, so nothing may be skipped or
    /// repacked). The fan-out constraint (eq. 7) is deliberately not
    /// enforced: generated rows never occupy MEM_S&N, which is what the
    /// limit protects.
    fn validate_conv(&self, layer: &QuantLayer, cfg: &AcceleratorConfig) -> Result<()> {
        let m = cfg.a_neurons_per_core;
        let n = cfg.virtual_per_a_neuron;
        let capacity = m * n;
        if !self.unassigned.is_empty() {
            bail!("conv mapping must assign every destination neuron");
        }
        let want_rounds = layer.out_dim.div_ceil(capacity);
        if self.rounds.len() != want_rounds {
            bail!(
                "conv mapping has {} rounds, canonical layout needs {want_rounds}",
                self.rounds.len()
            );
        }
        for (ri, round) in self.rounds.iter().enumerate() {
            let lo = ri * capacity;
            let hi = ((ri + 1) * capacity).min(layer.out_dim);
            if round.slot_of.len() != hi - lo {
                bail!(
                    "conv round {ri} holds {} neurons, canonical layout needs {}",
                    round.slot_of.len(),
                    hi - lo
                );
            }
            for (&i, &(j, k)) in &round.slot_of {
                let d = i as usize;
                if d < lo || d >= hi {
                    bail!("conv round {ri}: neuron {i} outside canonical range {lo}..{hi}");
                }
                let pos = d - lo;
                if (j as usize, k as usize) != (pos / n, pos % n) {
                    bail!(
                        "conv round {ri}: neuron {i} at slot ({j},{k}), canonical is ({},{})",
                        pos / n,
                        pos % n
                    );
                }
            }
        }
        Ok(())
    }

    /// Total assigned neurons.
    pub fn assigned_count(&self) -> usize {
        self.rounds.iter().map(|r| r.slot_of.len()).sum()
    }

    /// Peak weighted (in-degree) engine load across rounds — the
    /// communication-balance metric the refinement pass minimizes.
    pub fn peak_engine_load(&self, layer: &QuantLayer, m: usize) -> usize {
        let in_deg = in_degrees(layer);
        let mut peak = 0usize;
        for round in &self.rounds {
            let mut load = vec![0usize; m];
            for (&i, &(j, _)) in &round.slot_of {
                load[j as usize] += in_deg[i as usize];
            }
            peak = peak.max(load.into_iter().max().unwrap_or(0));
        }
        peak
    }
}

/// In-degree (number of incoming non-zero synapses) per destination neuron.
/// Works for both layer representations (generated rows for compressed
/// conv layers).
pub fn in_degrees(layer: &QuantLayer) -> Vec<usize> {
    let mut deg = vec![0usize; layer.out_dim];
    for s in 0..layer.in_dim {
        layer.for_each_target(s, |d, _| deg[d as usize] += 1);
    }
    deg
}

/// Map one layer onto one MX-NEURACORE with the chosen strategy.
///
/// Neurons with no incoming connections are skipped (they can never fire;
/// mapping them would waste capacitors — the paper prunes them away).
pub fn map_layer(
    layer: &QuantLayer,
    cfg: &AcceleratorConfig,
    strategy: Strategy,
) -> Result<LayerMapping> {
    if layer.conv.is_some() {
        // Conv layers take the canonical arithmetical layout regardless of
        // strategy — the generator-based row fetch computes slots from the
        // destination id, so placement freedom would buy nothing and cost a
        // per-event table lookup. Applying it to the expansion oracle too
        // keeps the two representations bit-comparable.
        return Ok(map_conv_canonical(layer, cfg, strategy));
    }
    let m = cfg.a_neurons_per_core;
    let n = cfg.virtual_per_a_neuron;
    let capacity = m * n;
    let in_deg = in_degrees(layer);
    // Active neurons, heaviest first (heavy neurons are hardest to place
    // and drive the balance objective).
    let mut active: Vec<u32> = (0..layer.out_dim as u32)
        .filter(|&i| in_deg[i as usize] > 0)
        .collect();
    active.sort_by_key(|&i| std::cmp::Reverse(in_deg[i as usize]));

    // Source lists per destination (transposed CSR) — needed for the
    // fan-out budget bookkeeping below.
    let mut sources_of: Vec<Vec<u32>> = vec![Vec::new(); layer.out_dim];
    for s in 0..layer.in_dim {
        for &(d, _) in layer.targets_of(s) {
            sources_of[d as usize].push(s as u32);
        }
    }

    // Partition into rounds of ≤ capacity respecting per-round fan-out
    // budgets (eq. 7): greedy bin packing in heavy-first order.
    let mut rounds_members: Vec<Vec<u32>> = Vec::new();
    let mut unassigned: Vec<u32> = Vec::new();
    {
        let mut remaining = active.clone();
        while !remaining.is_empty() {
            let mut round: Vec<u32> = Vec::new();
            let mut fanout = vec![0usize; layer.in_dim];
            let mut deferred: Vec<u32> = Vec::new();
            for &i in &remaining {
                if round.len() >= capacity {
                    deferred.push(i);
                    continue;
                }
                // Would adding i violate any source budget?
                let ok = sources_of[i as usize]
                    .iter()
                    .all(|&s| fanout[s as usize] + 1 <= cfg.fanout_limit);
                if ok {
                    for &s in &sources_of[i as usize] {
                        fanout[s as usize] += 1;
                    }
                    round.push(i);
                } else {
                    deferred.push(i);
                }
            }
            if round.is_empty() {
                // fanout_limit == 0: the rest can never be placed.
                unassigned = deferred;
                break;
            }
            rounds_members.push(round);
            remaining = deferred;
        }
    }

    // Assign slots within each round.
    let mut solver_nodes = 0usize;
    let mut rounds = Vec::with_capacity(rounds_members.len());
    for members in &rounds_members {
        let assign = match strategy {
            Strategy::IlpExact => {
                let (a, nodes) =
                    assign_ilp_exact(layer, members, m, n, cfg.fanout_limit)?;
                solver_nodes += nodes;
                a
            }
            Strategy::IlpFlow => assign_flow(members, m, n, &in_deg),
            Strategy::Greedy => assign_greedy(members, m, n, &in_deg),
            Strategy::FirstFit => assign_first_fit(members, m, n),
            Strategy::RoundRobin => assign_round_robin(members, m, n),
        };
        rounds.push(assign);
    }

    Ok(LayerMapping { rounds, unassigned, strategy, solver_nodes })
}

/// The canonical conv slot layout: destination `d` is assigned to round
/// `d/(M·N)`, engine `pos/N`, capacitor `pos%N` with `pos = d mod M·N` —
/// including destinations with no incoming connections, so the engine's
/// generator ([`crate::engine::ConvGen`]) can derive any destination's slot
/// arithmetically without a placement table.
fn map_conv_canonical(
    layer: &QuantLayer,
    cfg: &AcceleratorConfig,
    strategy: Strategy,
) -> LayerMapping {
    let n = cfg.virtual_per_a_neuron;
    let capacity = cfg.a_neurons_per_core * n;
    let num_rounds = layer.out_dim.div_ceil(capacity);
    let mut rounds = Vec::with_capacity(num_rounds);
    for ri in 0..num_rounds {
        let lo = ri * capacity;
        let hi = ((ri + 1) * capacity).min(layer.out_dim);
        let mut round = RoundAssignment::default();
        for d in lo..hi {
            let pos = d - lo;
            round.slot_of.insert(d as u32, ((pos / n) as u16, (pos % n) as u16));
        }
        rounds.push(round);
    }
    LayerMapping { rounds, unassigned: vec![], strategy, solver_nodes: 0 }
}

/// Map every layer of a network onto the accelerator's core chain.
pub fn map_network(
    net: &QuantNetwork,
    cfg: &AcceleratorConfig,
    strategy: Strategy,
) -> Result<Vec<LayerMapping>> {
    if net.layers.len() > cfg.num_cores {
        bail!(
            "network has {} layers but {} provides only {} MX-NEURACOREs",
            net.layers.len(),
            cfg.name,
            cfg.num_cores
        );
    }
    net.layers.iter().map(|l| map_layer(l, cfg, strategy)).collect()
}

// ---------------------------------------------------------------------------
// Strategy implementations (one round each; `members.len() ≤ m·n`).
// ---------------------------------------------------------------------------

/// Literal eqs. (3)–(7) ILP via branch & bound (small instances).
fn assign_ilp_exact(
    layer: &QuantLayer,
    members: &[u32],
    m: usize,
    n: usize,
    fanout_limit: usize,
) -> Result<(RoundAssignment, usize)> {
    let mut p = Problem::minimize();
    // x_{i,j,k}: member index ii (position in `members`), engine j, cap k.
    let mut var = vec![vec![vec![0usize; n]; m]; members.len()];
    for (ii, &i) in members.iter().enumerate() {
        for (j, vj) in var[ii].iter_mut().enumerate() {
            for (k, v) in vj.iter_mut().enumerate() {
                // Objective (4): minimize Σ (1 - x) ≡ maximize Σ x.
                *v = p.add_binary(format!("x_{i}_{j}_{k}"), -1.0);
            }
        }
    }
    p.objective_offset = (members.len() * m * n) as f64;
    // (5) engine capacity.
    for j in 0..m {
        let mut terms = Vec::with_capacity(members.len() * n);
        for ii in 0..members.len() {
            for k in 0..n {
                terms.push((var[ii][j][k], 1.0));
            }
        }
        p.add_constraint(format!("cap_{j}"), terms, Cmp::Le, n as f64);
    }
    // (6) unique assignment — `≤ 1` plus the maximizing objective: the
    // paper's equality reading would make partial assignment infeasible
    // under capacity pressure, but eq. (4) explicitly tolerates unassigned
    // neurons, so ≤ is the consistent interpretation.
    for (ii, &i) in members.iter().enumerate() {
        let mut terms = Vec::with_capacity(m * n);
        for j in 0..m {
            for k in 0..n {
                terms.push((var[ii][j][k], 1.0));
            }
        }
        p.add_constraint(format!("uniq_{i}"), terms, Cmp::Le, 1.0);
    }
    // One neuron per capacitor.
    for j in 0..m {
        for k in 0..n {
            let terms: Vec<_> =
                (0..members.len()).map(|ii| (var[ii][j][k], 1.0)).collect();
            p.add_constraint(format!("slot_{j}_{k}"), terms, Cmp::Le, 1.0);
        }
    }
    // (7) fan-out per source neuron.
    for s in 0..layer.in_dim {
        let connected: Vec<usize> = members
            .iter()
            .enumerate()
            .filter(|(_, &i)| layer.targets_of(s).iter().any(|&(d, _)| d == i))
            .map(|(ii, _)| ii)
            .collect();
        if connected.len() > fanout_limit {
            let mut terms = Vec::with_capacity(connected.len() * m * n);
            for &ii in &connected {
                for j in 0..m {
                    for k in 0..n {
                        terms.push((var[ii][j][k], 1.0));
                    }
                }
            }
            p.add_constraint(format!("fanout_{s}"), terms, Cmp::Le, fanout_limit as f64);
        }
    }
    let sol = branch_bound::solve(&p, &BnbConfig::default());
    if sol.status != Status::Optimal && sol.status != Status::LimitReached {
        bail!("exact ILP solve failed: {:?}", sol.status);
    }
    let mut round = RoundAssignment::default();
    for (ii, &i) in members.iter().enumerate() {
        'place: for j in 0..m {
            for k in 0..n {
                if sol.is_one(var[ii][j][k]) {
                    round.slot_of.insert(i, (j as u16, k as u16));
                    break 'place;
                }
            }
        }
    }
    Ok((round, sol.nodes_explored))
}

/// Production path: transportation problem via min-cost max-flow.
///
/// Nodes: source → one node per member (cap 1) → engine nodes → sink.
/// Engine→sink is expanded into N unit edges with convexly increasing
/// costs, which (a) keeps the problem totally unimodular and (b) balances
/// neuron counts across engines. A local-refinement pass then swaps
/// assignments to balance *weighted* (in-degree) load.
fn assign_flow(members: &[u32], m: usize, n: usize, in_deg: &[usize]) -> RoundAssignment {
    let nm = members.len();
    // node ids: 0 = source, 1..=nm members, nm+1..=nm+m engines, nm+m+1 sink
    let s = 0usize;
    let member_node = |ii: usize| 1 + ii;
    let engine_node = |j: usize| 1 + nm + j;
    let t = 1 + nm + m;
    let mut g = McmfGraph::new(t + 1);
    for ii in 0..nm {
        g.add_edge(s, member_node(ii), 1, 0);
    }
    let mut member_engine_edges = vec![vec![(0usize, 0usize); m]; nm];
    for (ii, edges) in member_engine_edges.iter_mut().enumerate() {
        for (j, e) in edges.iter_mut().enumerate() {
            *e = g.add_edge(member_node(ii), engine_node(j), 1, 0);
        }
    }
    for j in 0..m {
        for k in 0..n {
            // Convex cost: k-th neuron on an engine costs k (balances counts).
            g.add_edge(engine_node(j), t, 1, k as i64);
        }
    }
    g.min_cost_flow(s, t, nm as i64);

    // Read engine choice per member from edge flows.
    let mut engine_of = vec![usize::MAX; nm];
    for (ii, edges) in member_engine_edges.iter().enumerate() {
        for (j, &e) in edges.iter().enumerate() {
            if g.edge_flow(e) > 0 {
                engine_of[ii] = j;
                break;
            }
        }
    }
    // Local refinement: balance weighted load by moving members from the
    // heaviest engine to the lightest while it helps (capacitors within an
    // engine are symmetric, so any move keeping counts ≤ n is feasible).
    let mut count = vec![0usize; m];
    let mut wload = vec![0i64; m];
    for (ii, &j) in engine_of.iter().enumerate() {
        count[j] += 1;
        wload[j] += in_deg[members[ii] as usize] as i64;
    }
    for _ in 0..4 * nm.max(1) {
        let (hi, _) = wload.iter().enumerate().max_by_key(|&(_, &w)| w).unwrap();
        let (lo, _) = wload.iter().enumerate().min_by_key(|&(_, &w)| w).unwrap();
        if hi == lo {
            break;
        }
        let gap = wload[hi] - wload[lo];
        if gap <= 1 {
            break;
        }
        if count[lo] < n {
            // Move: best member whose weight is closest to half the gap.
            let candidate = engine_of
                .iter()
                .enumerate()
                .filter(|&(_, &j)| j == hi)
                .map(|(ii, _)| (ii, in_deg[members[ii] as usize] as i64))
                .filter(|&(_, w)| w > 0 && w < gap)
                .min_by_key(|&(_, w)| (gap - 2 * w).abs());
            if let Some((ii, w)) = candidate {
                engine_of[ii] = lo;
                count[hi] -= 1;
                count[lo] += 1;
                wload[hi] -= w;
                wload[lo] += w;
                continue;
            }
        }
        // Swap: pair (a on hi, b on lo) with 0 < w_a - w_b < gap, transfer
        // closest to half the gap.
        let heavy: Vec<(usize, i64)> = engine_of
            .iter()
            .enumerate()
            .filter(|&(_, &j)| j == hi)
            .map(|(ii, _)| (ii, in_deg[members[ii] as usize] as i64))
            .collect();
        let light: Vec<(usize, i64)> = engine_of
            .iter()
            .enumerate()
            .filter(|&(_, &j)| j == lo)
            .map(|(ii, _)| (ii, in_deg[members[ii] as usize] as i64))
            .collect();
        let mut best: Option<(usize, usize, i64)> = None;
        for &(a, wa) in &heavy {
            for &(b, wb) in &light {
                let d = wa - wb;
                if d > 0 && d < gap {
                    let score = (gap - 2 * d).abs();
                    if best.map_or(true, |(_, _, bd)| score < (gap - 2 * bd).abs()) {
                        best = Some((a, b, d));
                    }
                }
            }
        }
        match best {
            Some((a, b, d)) => {
                engine_of[a] = lo;
                engine_of[b] = hi;
                wload[hi] -= d;
                wload[lo] += d;
            }
            None => break,
        }
    }
    let mut round = RoundAssignment::default();
    let mut next_cap = vec![0u16; m];
    for (ii, &i) in members.iter().enumerate() {
        let j = engine_of[ii];
        debug_assert!(j != usize::MAX, "flow must place every member");
        let k = next_cap[j];
        next_cap[j] += 1;
        round.slot_of.insert(i, (j as u16, k));
    }
    round
}

/// Greedy: heaviest neuron to the least-loaded engine (weighted load).
fn assign_greedy(members: &[u32], m: usize, n: usize, in_deg: &[usize]) -> RoundAssignment {
    let mut order: Vec<u32> = members.to_vec();
    order.sort_by_key(|&i| std::cmp::Reverse(in_deg[i as usize]));
    let mut round = RoundAssignment::default();
    let mut count = vec![0usize; m];
    let mut load = vec![0usize; m];
    for i in order {
        // Least weighted load among engines with free capacitors.
        let j = (0..m)
            .filter(|&j| count[j] < n)
            .min_by_key(|&j| (load[j], j))
            .expect("round size ≤ m·n guarantees a free slot");
        round.slot_of.insert(i, (j as u16, count[j] as u16));
        load[j] += in_deg[i as usize];
        count[j] += 1;
    }
    round
}

/// First-fit: members in index order fill engine 0 before engine 1, etc.
fn assign_first_fit(members: &[u32], m: usize, n: usize) -> RoundAssignment {
    let mut sorted: Vec<u32> = members.to_vec();
    sorted.sort_unstable();
    let mut round = RoundAssignment::default();
    for (pos, &i) in sorted.iter().enumerate() {
        let j = pos / n;
        let k = pos % n;
        if j >= m {
            break;
        }
        round.slot_of.insert(i, (j as u16, k as u16));
    }
    round
}

/// Round-robin: members distributed cyclically across engines.
fn assign_round_robin(members: &[u32], m: usize, n: usize) -> RoundAssignment {
    let mut sorted: Vec<u32> = members.to_vec();
    sorted.sort_unstable();
    let mut round = RoundAssignment::default();
    let mut count = vec![0u16; m];
    for (pos, &i) in sorted.iter().enumerate() {
        // Find next engine with space starting from pos % m.
        let mut j = pos % m;
        let mut tries = 0;
        while count[j] as usize >= n && tries < m {
            j = (j + 1) % m;
            tries += 1;
        }
        if tries == m {
            break;
        }
        round.slot_of.insert(i, (j as u16, count[j]));
        count[j] += 1;
    }
    round
}

// ---------------------------------------------------------------------------
// Distiller: mapping → controller memory images (paper Figure 4).
// ---------------------------------------------------------------------------

/// One engine column of a MEM_S&N row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SnEntry {
    /// Virtual-neuron (capacitor) index inside the A-NEURON.
    pub virt: u16,
    /// Address of the synaptic weight in the A-SYN weight SRAM.
    pub weight_addr: u32,
    /// Destination neuron id (simulation convenience; the silicon encodes
    /// it implicitly via (engine, virt, round)).
    pub dst: u32,
}

/// One MEM_S&N row: per A-NEURON column group, an optional
/// (virtual index, weight address) pair; the paper's `NI_j` binary flag is
/// `per_engine[j].is_some()`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SnRow {
    pub per_engine: Vec<Option<SnEntry>>,
}

/// MEM_E2A entry: `B_i` rows starting at address `A_i` (paper Figure 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct E2aEntry {
    pub count: u32,
    pub start: u32,
}

/// Control memories for one round of one MX-NEURACORE.
#[derive(Debug, Clone, Default)]
pub struct RoundImage {
    /// Indexed by source neuron id.
    pub e2a: Vec<E2aEntry>,
    pub sn_rows: Vec<SnRow>,
    /// (engine, virt) → destination neuron resident this round.
    pub residents: BTreeMap<Slot, u32>,
}

/// Full control-memory image for one MX-NEURACORE.
#[derive(Debug, Clone)]
pub struct CoreImage {
    pub rounds: Vec<RoundImage>,
    /// A-SYN weight SRAM contents.
    pub weight_mem: Vec<i8>,
    /// Dequantization scale of the layer.
    pub scale: f32,
    /// Number of A-NEURON engines (M) the image was distilled for.
    pub num_engines: usize,
    /// in/out dims of the layer (for checking).
    pub in_dim: usize,
    pub out_dim: usize,
    /// `Some` when this image is a **compressed** conv layer: `weight_mem`
    /// holds the `[oc][ic][kh][kw]` kernel and the engine generates synapse
    /// rows from it at dispatch time instead of reading `e2a`/`sn_rows`
    /// (which stay empty). `None` for dense/CSR images — including the
    /// conv expansion oracle, which executes through the MEM_S&N path.
    pub conv: Option<ConvSpec>,
}

impl CoreImage {
    /// Peak MEM_S&N rows across rounds (capacity check + Figs 6–7 input).
    pub fn peak_sn_rows(&self) -> usize {
        self.rounds.iter().map(|r| r.sn_rows.len()).max().unwrap_or(0)
    }
}

/// Distill a layer mapping into the controller memory image (Figure 4).
///
/// For each round and each source neuron, connections to resident
/// destination neurons are packed into MEM_S&N rows — one destination per
/// engine column per row, exactly the paper's layout ("since a source
/// neuron may be connected to more than M available A-NEURONs, its
/// connections may be defined in a couple of rows").
pub fn distill(
    layer: &QuantLayer,
    mapping: &LayerMapping,
    cfg: &AcceleratorConfig,
) -> Result<CoreImage> {
    if layer.is_compressed() {
        return distill_conv(layer, mapping, cfg);
    }
    let m = cfg.a_neurons_per_core;
    let mut weight_mem: Vec<i8> = Vec::new();
    let mut rounds = Vec::with_capacity(mapping.rounds.len());

    for round in &mapping.rounds {
        let mut img = RoundImage {
            e2a: vec![E2aEntry::default(); layer.in_dim],
            sn_rows: Vec::new(),
            residents: round.slot_of.iter().map(|(&i, &slot)| (slot, i)).collect(),
        };
        for s in 0..layer.in_dim {
            // Connections from s to neurons resident this round, grouped by
            // engine.
            let mut per_engine: Vec<Vec<(u16, u32, i8)>> = vec![Vec::new(); m];
            for &(d, w) in layer.targets_of(s) {
                if let Some(&(j, k)) = round.slot_of.get(&d) {
                    per_engine[j as usize].push((k, d, w));
                }
            }
            let rows_needed = per_engine.iter().map(|v| v.len()).max().unwrap_or(0);
            if rows_needed == 0 {
                continue;
            }
            let start = img.sn_rows.len() as u32;
            for r in 0..rows_needed {
                let mut row = SnRow { per_engine: vec![None; m] };
                for (j, conns) in per_engine.iter().enumerate() {
                    if let Some(&(k, d, w)) = conns.get(r) {
                        let weight_addr = weight_mem.len() as u32;
                        weight_mem.push(w);
                        row.per_engine[j] =
                            Some(SnEntry { virt: k, weight_addr, dst: d });
                    }
                }
                img.sn_rows.push(row);
            }
            img.e2a[s] = E2aEntry { count: rows_needed as u32, start };
        }
        if img.sn_rows.len() > cfg.memsn_rows {
            bail!(
                "round needs {} MEM_S&N rows, core provides {}",
                img.sn_rows.len(),
                cfg.memsn_rows
            );
        }
        rounds.push(img);
    }

    if weight_mem.len() > cfg.weight_capacity() {
        bail!(
            "layer needs {} weights, core weight SRAM holds {}",
            weight_mem.len(),
            cfg.weight_capacity()
        );
    }

    Ok(CoreImage {
        rounds,
        weight_mem,
        scale: layer.scale,
        num_engines: m,
        in_dim: layer.in_dim,
        out_dim: layer.out_dim,
        conv: None,
    })
}

/// Distill a **compressed** conv layer: the A-SYN weight SRAM holds the
/// kernel once, and MEM_E2A/MEM_S&N stay empty — at dispatch time the
/// engine enumerates each source's rows arithmetically from the kernel
/// ([`crate::engine::ConvGen`]), which is the whole point of synapse
/// compression (arxiv 2112.07019). Only the per-round residents (the
/// canonical slot layout, needed for sweeps and multi-round reloads) are
/// materialized.
fn distill_conv(
    layer: &QuantLayer,
    mapping: &LayerMapping,
    cfg: &AcceleratorConfig,
) -> Result<CoreImage> {
    if layer.kernel.len() > cfg.weight_capacity() {
        bail!(
            "conv kernel needs {} weights, core weight SRAM holds {}",
            layer.kernel.len(),
            cfg.weight_capacity()
        );
    }
    let rounds = mapping
        .rounds
        .iter()
        .map(|round| RoundImage {
            e2a: Vec::new(),
            sn_rows: Vec::new(),
            residents: round.slot_of.iter().map(|(&i, &slot)| (slot, i)).collect(),
        })
        .collect();
    Ok(CoreImage {
        rounds,
        weight_mem: layer.kernel.clone(),
        scale: layer.scale,
        num_engines: cfg.a_neurons_per_core,
        in_dim: layer.in_dim,
        out_dim: layer.out_dim,
        conv: layer.conv,
    })
}

/// Distill every layer of a mapped network.
pub fn distill_network(
    net: &QuantNetwork,
    mappings: &[LayerMapping],
    cfg: &AcceleratorConfig,
) -> Result<Vec<CoreImage>> {
    if mappings.len() != net.layers.len() {
        bail!("{} mappings for {} layers", mappings.len(), net.layers.len());
    }
    net.layers
        .iter()
        .zip(mappings)
        .map(|(l, mp)| distill(l, mp, cfg))
        .collect()
}

// ---------------------------------------------------------------------------
// Cross-chip pipeline partitioner (multi-chip sharding).
// ---------------------------------------------------------------------------

/// Estimated inter-shard spike traffic of cutting the pipeline after each
/// layer: `costs[b]` prices the boundary between layer `b` and `b+1`
/// (`b ∈ 0..layers-1`).
///
/// The events crossing a cut per time step are the boundary layer's output
/// spikes (train width `out_dim(b)`), and each forwarded spike triggers a
/// MEM_E2A lookup plus a fan-out walk in the next shard's first core — in
/// expectation `nnz(b+1)/out_dim(b)` synapse rows per spike. Scaling by
/// the boundary width gives the static per-step estimate
/// `out_dim(b) + nnz(b+1)`: wide, densely fanned-out boundaries are
/// expensive cuts, pruned narrow ones are cheap — exactly the traffic
/// bottleneck the multi-core routing literature optimizes for.
///
/// Deliberately representation-independent: `nnz()` is the *logical*
/// synapse count, identical for a compressed conv layer and its expansion
/// — cut traffic depends on spikes and fan-out walks, not on how weights
/// are stored. Compression pays off through [`layer_weight_bytes`] (fewer
/// shards needed for the same budget), not through cheaper cuts.
pub fn shard_cut_costs(net: &QuantNetwork) -> Vec<u64> {
    net.layers
        .windows(2)
        .map(|w| w[0].out_dim as u64 + w[1].nnz() as u64)
        .collect()
}

/// Per-layer A-SYN weight-SRAM footprint in bytes — the quantity the
/// per-chip memory budget constrains. Counts the weights [`distill`]
/// actually emits (one per non-zero synapse for dense layers, the kernel
/// taps once for compressed conv layers) bit-packed at the quantized
/// `weight_bits` width. Synapse compression shows up exactly here: a conv
/// layer drops from `nnz` stored weights to `oc·ic·kh·kw`.
pub fn layer_weight_bytes(net: &QuantNetwork, weight_bits: u32) -> Vec<usize> {
    net.layers
        .iter()
        .map(|l| (l.stored_weights() * weight_bits as usize).div_ceil(8))
        .collect()
}

/// Per-chip capacity limits the shard partitioner must respect.
#[derive(Debug, Clone, Copy)]
pub struct ShardLimits {
    /// A chip hosts one layer per MX-NEURACORE, so a shard can carry at
    /// most this many layers (= the chip's `num_cores`).
    pub max_layers_per_shard: usize,
    /// Optional aggregate weight-SRAM budget per chip (bytes across the
    /// shard's layers). `None` = unconstrained.
    pub chip_weight_budget: Option<usize>,
    /// Quantized weight width in bits — sets how [`layer_weight_bytes`]
    /// packs stored weights when charging against the budget.
    pub weight_bits: u32,
}

impl ShardLimits {
    /// Limits implied by an accelerator preset: one layer per core, the
    /// preset's weight width, no aggregate weight budget beyond the
    /// per-core SRAM already enforced by the distiller.
    pub fn from_accel(cfg: &AcceleratorConfig) -> Self {
        Self {
            max_layers_per_shard: cfg.num_cores,
            chip_weight_budget: None,
            weight_bits: cfg.weight_bits,
        }
    }
}

/// A layer→shard assignment for pipeline-parallel multi-chip execution.
/// Shards are contiguous layer ranges in pipeline order (layer `l` feeds
/// `l+1`, so any non-contiguous assignment would route traffic through a
/// chip twice).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// `shard_of[l]` = shard index of layer `l`; non-decreasing, starting
    /// at 0, covering `0..num_shards`.
    pub shard_of: Vec<usize>,
    pub num_shards: usize,
    /// Total estimated inter-shard traffic over the chosen cuts
    /// ([`shard_cut_costs`] summed over boundaries where the shard index
    /// changes).
    pub cut_cost: u64,
    /// Branch-and-bound nodes explored (0 for the DP path).
    pub solver_nodes: usize,
}

impl ShardPlan {
    /// A trivial single-shard plan over `layers` layers.
    pub fn monolithic(layers: usize) -> Self {
        Self { shard_of: vec![0; layers], num_shards: 1, cut_cost: 0, solver_nodes: 0 }
    }

    /// Contiguous layer range of each shard.
    pub fn ranges(&self) -> Vec<std::ops::Range<usize>> {
        let mut ranges = Vec::with_capacity(self.num_shards);
        let mut start = 0usize;
        for s in 0..self.num_shards {
            let end = start
                + self.shard_of[start..].iter().take_while(|&&x| x == s).count();
            ranges.push(start..end);
            start = end;
        }
        ranges
    }

    /// Boundary indices (cut after layer `b`) where shards change.
    pub fn cuts(&self) -> Vec<usize> {
        self.shard_of
            .windows(2)
            .enumerate()
            .filter(|(_, w)| w[0] != w[1])
            .map(|(b, _)| b)
            .collect()
    }

    /// Check structural validity and the per-chip capacity limits.
    pub fn validate(&self, net: &QuantNetwork, limits: &ShardLimits) -> Result<()> {
        let l = net.layers.len();
        if self.shard_of.len() != l {
            bail!("plan covers {} layers, network has {l}", self.shard_of.len());
        }
        if self.num_shards == 0 || self.shard_of.first() != Some(&0) {
            bail!("plan must start at shard 0 with ≥1 shard");
        }
        for (b, w) in self.shard_of.windows(2).enumerate() {
            if w[1] != w[0] && w[1] != w[0] + 1 {
                bail!("shard index jumps {}→{} after layer {b} (must be contiguous)", w[0], w[1]);
            }
        }
        if self.shard_of.last() != Some(&(self.num_shards - 1)) {
            bail!(
                "last layer on shard {:?}, expected {} (every shard must be non-empty)",
                self.shard_of.last(),
                self.num_shards - 1
            );
        }
        let weights = layer_weight_bytes(net, limits.weight_bits);
        for (s, range) in self.ranges().into_iter().enumerate() {
            let count = range.len();
            if count == 0 {
                bail!("shard {s} is empty");
            }
            if count > limits.max_layers_per_shard {
                bail!(
                    "shard {s} holds {count} layers, chip provides {} cores",
                    limits.max_layers_per_shard
                );
            }
            if let Some(budget) = limits.chip_weight_budget {
                let bytes: usize = weights[range.clone()].iter().sum();
                if bytes > budget {
                    bail!("shard {s} needs {bytes} weight bytes, chip budget is {budget}");
                }
            }
        }
        let costs = shard_cut_costs(net);
        let actual: u64 = self.cuts().iter().map(|&b| costs[b]).sum();
        if actual != self.cut_cost {
            bail!("plan cut_cost {} != recomputed {actual}", self.cut_cost);
        }
        Ok(())
    }
}

/// Shared feasibility preamble for both partitioner paths.
fn partition_check(net: &QuantNetwork, num_shards: usize, limits: &ShardLimits) -> Result<()> {
    let l = net.layers.len();
    if num_shards == 0 {
        bail!("cannot partition into 0 shards");
    }
    if num_shards > l {
        bail!("cannot split {l} layers into {num_shards} non-empty shards");
    }
    if let Some(budget) = limits.chip_weight_budget {
        let weights = layer_weight_bytes(net, limits.weight_bits);
        if let Some((i, &w)) = weights.iter().enumerate().find(|(_, &w)| w > budget) {
            bail!("layer {i} alone needs {w} weight bytes, chip budget is {budget}");
        }
    }
    Ok(())
}

fn plan_from_cuts(
    net: &QuantNetwork,
    cut_after: &[bool],
    num_shards: usize,
    solver_nodes: usize,
) -> ShardPlan {
    let costs = shard_cut_costs(net);
    let mut shard_of = Vec::with_capacity(net.layers.len());
    let mut s = 0usize;
    let mut cut_cost = 0u64;
    for l in 0..net.layers.len() {
        shard_of.push(s);
        if l + 1 < net.layers.len() && cut_after[l] {
            cut_cost += costs[l];
            s += 1;
        }
    }
    ShardPlan { shard_of, num_shards, cut_cost, solver_nodes }
}

/// Partition the pipeline into exactly `num_shards` contiguous shards
/// minimizing total inter-shard spike traffic ([`shard_cut_costs`]) under
/// the per-chip capacity `limits`. Exact: contiguous chain partitioning is
/// solved by dynamic programming over (layers-consumed, shards-used); the
/// ILP formulation ([`partition_layers_ilp`]) is pinned to the same
/// optimum by unit test.
pub fn partition_layers(
    net: &QuantNetwork,
    num_shards: usize,
    limits: &ShardLimits,
) -> Result<ShardPlan> {
    partition_check(net, num_shards, limits)?;
    let l = net.layers.len();
    let costs = shard_cut_costs(net);
    let weights = layer_weight_bytes(net, limits.weight_bits);
    let cmax = limits.max_layers_per_shard.max(1);
    const INF: u64 = u64::MAX;
    // dp[k][i]: min cut cost placing layers 0..i on k chips.
    let mut dp = vec![vec![INF; l + 1]; num_shards + 1];
    let mut from = vec![vec![usize::MAX; l + 1]; num_shards + 1];
    dp[0][0] = 0;
    for k in 1..=num_shards {
        for i in k..=l {
            // Last shard = layers j..i (j decreasing grows the segment).
            let mut wsum = 0usize;
            for j in (k - 1..i).rev() {
                if i - j > cmax {
                    break;
                }
                wsum += weights[j];
                if limits.chip_weight_budget.is_some_and(|b| wsum > b) {
                    break;
                }
                if dp[k - 1][j] == INF {
                    continue;
                }
                let cut = if j == 0 { 0 } else { costs[j - 1] };
                let cand = dp[k - 1][j] + cut;
                if cand < dp[k][i] {
                    dp[k][i] = cand;
                    from[k][i] = j;
                }
            }
        }
    }
    if dp[num_shards][l] == INF {
        bail!(
            "no feasible {num_shards}-way partition of {l} layers \
             (≤{cmax} layers/chip{})",
            limits
                .chip_weight_budget
                .map(|b| format!(", ≤{b} weight bytes/chip"))
                .unwrap_or_default()
        );
    }
    let mut cut_after = vec![false; l.saturating_sub(1)];
    let (mut k, mut i) = (num_shards, l);
    while k > 0 {
        let j = from[k][i];
        if j > 0 {
            cut_after[j - 1] = true;
        }
        i = j;
        k -= 1;
    }
    let plan = plan_from_cuts(net, &cut_after, num_shards, 0);
    debug_assert_eq!(plan.cut_cost, dp[num_shards][l]);
    plan.validate(net, limits)?;
    Ok(plan)
}

/// The same partitioning problem posed as an explicit ILP over boundary
/// binaries `y_b` ("cut after layer b"), solved by the in-tree branch &
/// bound: minimize `Σ cost_b·y_b` subject to exactly `num_shards − 1` cuts
/// and sliding-window covering constraints — any `max_layers_per_shard`
/// consecutive boundaries must contain a cut (else some chip hosts more
/// layers than it has cores), and any minimal layer window whose weights
/// exceed the chip budget must contain a cut.
///
/// [`partition_layers`] (the DP) is the production path; this certifies it
/// and keeps the solver honest on a second ILP family (equality +
/// covering constraints, unlike the assignment ILP of eqs. 3–7).
pub fn partition_layers_ilp(
    net: &QuantNetwork,
    num_shards: usize,
    limits: &ShardLimits,
) -> Result<ShardPlan> {
    partition_check(net, num_shards, limits)?;
    let l = net.layers.len();
    let costs = shard_cut_costs(net);
    let weights = layer_weight_bytes(net, limits.weight_bits);
    let cmax = limits.max_layers_per_shard.max(1);
    if num_shards == 1 {
        let plan = ShardPlan::monolithic(l);
        plan.validate(net, limits)?;
        return Ok(plan);
    }
    let mut p = Problem::minimize();
    let y: Vec<usize> =
        (0..l - 1).map(|b| p.add_binary(format!("cut_{b}"), costs[b] as f64)).collect();
    p.add_exactly_k("num_cuts", &y, (num_shards - 1) as f64);
    // Core capacity: boundaries i..i+cmax span cmax+1 layers — cut-free,
    // they would put cmax+1 layers on one chip.
    if cmax < l {
        for i in 0..=(l - 1 - cmax) {
            p.add_cover(format!("len_window_{i}"), &y[i..i + cmax]);
        }
    }
    // Weight budget: minimal over-budget layer windows [a..=d] need a cut
    // strictly inside (boundaries a..d). Minimal windows dominate larger
    // ones, so these suffice.
    if let Some(budget) = limits.chip_weight_budget {
        for a in 0..l {
            let mut wsum = 0usize;
            for d in a..l {
                wsum += weights[d];
                if wsum > budget {
                    // partition_check rejected single over-budget layers,
                    // so d > a and the boundary range is non-empty.
                    p.add_cover(format!("weight_window_{a}"), &y[a..d]);
                    break;
                }
            }
        }
    }
    let sol = branch_bound::solve(&p, &BnbConfig::default());
    if sol.status != Status::Optimal && sol.status != Status::LimitReached {
        bail!("shard partition ILP solve failed: {:?}", sol.status);
    }
    let cut_after: Vec<bool> = y.iter().map(|&v| sol.is_one(v)).collect();
    let plan = plan_from_cuts(net, &cut_after, num_shards, sol.nodes_explored);
    plan.validate(net, limits)?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::LifParams;
    use crate::util::rng::Rng;

    fn small_cfg(m: usize, n: usize) -> AcceleratorConfig {
        let mut c = AcceleratorConfig::accel1();
        c.a_neurons_per_core = m;
        c.a_syns_per_core = m;
        c.virtual_per_a_neuron = n;
        c
    }

    fn random_layer(in_dim: usize, out_dim: usize, sparsity: f64, seed: u64) -> QuantLayer {
        let mut rng = Rng::new(seed);
        let mut w = vec![0i8; in_dim * out_dim];
        for x in w.iter_mut() {
            if !rng.bernoulli(sparsity) {
                *x = rng.range_inclusive(-127, 127) as i8;
            }
        }
        QuantLayer::new(in_dim, out_dim, w, 0.01, LifParams::default()).unwrap()
    }

    #[test]
    fn all_strategies_produce_valid_mappings() {
        let layer = random_layer(20, 30, 0.5, 1);
        let cfg = small_cfg(4, 4); // capacity 16 < 30 -> ≥2 rounds
        for strat in Strategy::all() {
            if strat == Strategy::IlpExact {
                continue; // exercised separately on a smaller instance
            }
            let mp = map_layer(&layer, &cfg, strat).unwrap();
            mp.validate(&layer, &cfg)
                .unwrap_or_else(|e| panic!("{}: {e}", strat.name()));
            assert!(mp.rounds.len() >= 2, "{}: rounds={}", strat.name(), mp.rounds.len());
            assert!(mp.unassigned.is_empty());
        }
    }

    #[test]
    fn ilp_exact_small_layer() {
        let layer = random_layer(6, 8, 0.3, 2);
        let cfg = small_cfg(2, 2); // capacity 4 -> 2 rounds
        let mp = map_layer(&layer, &cfg, Strategy::IlpExact).unwrap();
        mp.validate(&layer, &cfg).unwrap();
        assert_eq!(mp.assigned_count(), 8);
        assert!(mp.solver_nodes > 0);
    }

    #[test]
    fn flow_matches_exact_assignment_count() {
        // On instances where everything fits, both must assign everything
        // (the eq. (4) optimum is 0 unassigned).
        for seed in 0..5 {
            let layer = random_layer(10, 6, 0.4, seed);
            let cfg = small_cfg(3, 3);
            let exact = map_layer(&layer, &cfg, Strategy::IlpExact).unwrap();
            let flow = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
            assert_eq!(exact.assigned_count(), flow.assigned_count(), "seed {seed}");
            flow.validate(&layer, &cfg).unwrap();
            exact.validate(&layer, &cfg).unwrap();
        }
    }

    #[test]
    fn flow_balances_no_worse_than_first_fit() {
        let layer = random_layer(40, 24, 0.3, 7);
        let cfg = small_cfg(4, 6);
        let flow = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        let ff = map_layer(&layer, &cfg, Strategy::FirstFit).unwrap();
        let m = cfg.a_neurons_per_core;
        assert!(
            flow.peak_engine_load(&layer, m) <= ff.peak_engine_load(&layer, m),
            "flow peak {} > first-fit peak {}",
            flow.peak_engine_load(&layer, m),
            ff.peak_engine_load(&layer, m)
        );
    }

    #[test]
    fn skips_dead_neurons() {
        // weights row-major [out][in]: dst0<-src0 (5), dst1 dead, dst2<-src1 (7)
        let layer = QuantLayer::new(
            2,
            3,
            vec![5, 0, 0, 0, 0, 7],
            0.1,
            LifParams::default(),
        )
        .unwrap();
        let cfg = small_cfg(2, 2);
        let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        let assigned: Vec<u32> =
            mp.rounds.iter().flat_map(|r| r.slot_of.keys().copied()).collect();
        assert!(assigned.contains(&0));
        assert!(!assigned.contains(&1), "dead neuron mapped");
        assert!(assigned.contains(&2));
        mp.validate(&layer, &cfg).unwrap();
    }

    #[test]
    fn validate_rejects_broken_mappings() {
        let layer = random_layer(5, 4, 0.2, 3);
        let cfg = small_cfg(2, 2);
        let mut mp = map_layer(&layer, &cfg, Strategy::Greedy).unwrap();
        // Duplicate assignment.
        let first = *mp.rounds[0].slot_of.keys().next().unwrap();
        mp.rounds.push(RoundAssignment {
            slot_of: [(first, (0u16, 0u16))].into_iter().collect(),
        });
        assert!(mp.validate(&layer, &cfg).is_err());
    }

    #[test]
    fn distiller_layout_matches_figure4() {
        // 3 sources, 4 dsts on 2 engines × 2 caps; src0 connects to all 4
        // dsts -> needs ≥2 rows (≤2 engine columns per row).
        let mut w = vec![0i8; 4 * 3];
        for d in 0..4 {
            w[d * 3] = (d + 1) as i8; // src 0 -> every dst
        }
        w[3 + 1] = 9; // dst1 <- src1
        let layer = QuantLayer::new(3, 4, w, 0.1, LifParams::default()).unwrap();
        let cfg = small_cfg(2, 2);
        let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        let img = distill(&layer, &mp, &cfg).unwrap();
        assert_eq!(img.rounds.len(), 1);
        let r = &img.rounds[0];
        // src0: 4 connections over 2 engines -> B_0 = 2 rows.
        assert_eq!(r.e2a[0].count, 2, "src0 rows");
        assert_eq!(r.e2a[1].count, 1, "src1 rows");
        assert_eq!(r.e2a[2].count, 0, "src2 has no connections");
        // Every connection appears exactly once with the right weight.
        let mut weights: Vec<i8> = r
            .sn_rows
            .iter()
            .flat_map(|row| row.per_engine.iter().flatten())
            .map(|e| img.weight_mem[e.weight_addr as usize])
            .collect();
        weights.sort_unstable();
        assert_eq!(weights, vec![1, 2, 3, 4, 9]);
    }

    #[test]
    fn distiller_respects_capacity_limits() {
        let layer = random_layer(8, 8, 0.0, 4); // dense
        let mut cfg = small_cfg(4, 2);
        cfg.memsn_rows = 1; // absurdly small
        let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        assert!(distill(&layer, &mp, &cfg).is_err());
        let mut cfg2 = small_cfg(4, 2);
        cfg2.weight_mem_bytes = 4; // 4 weights max
        let mp2 = map_layer(&layer, &cfg2, Strategy::IlpFlow).unwrap();
        assert!(distill(&layer, &mp2, &cfg2).is_err());
    }

    #[test]
    fn residents_inverse_of_slots() {
        let layer = random_layer(12, 10, 0.4, 9);
        let cfg = small_cfg(3, 4);
        let mp = map_layer(&layer, &cfg, Strategy::Greedy).unwrap();
        let img = distill(&layer, &mp, &cfg).unwrap();
        for (round, rimg) in mp.rounds.iter().zip(&img.rounds) {
            for (&i, &slot) in &round.slot_of {
                assert_eq!(rimg.residents.get(&slot), Some(&i));
            }
        }
    }

    #[test]
    fn map_network_checks_core_count() {
        let mut rng = Rng::new(1);
        let cfg_model = crate::config::ModelConfig {
            name: "t".into(),
            layer_sizes: vec![10, 8, 6, 4, 2, 2],
            timesteps: 3,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        };
        let net = QuantNetwork::random(&cfg_model, 0.5, &mut rng);
        let cfg = small_cfg(2, 4); // accel1 base: 4 cores < 5 layers
        assert!(map_network(&net, &cfg, Strategy::Greedy).is_err());
    }

    #[test]
    fn fanout_constraint_partitions_rounds() {
        // One source fans out to 6 dsts; fanout_limit 2 forces ≥3 rounds.
        let w = vec![1i8; 6]; // [out=6][in=1]
        let layer = QuantLayer::new(1, 6, w, 0.1, LifParams::default()).unwrap();
        let mut cfg = small_cfg(3, 4); // capacity 12 — no capacity pressure
        cfg.fanout_limit = 2;
        let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        assert!(mp.rounds.len() >= 3, "rounds={}", mp.rounds.len());
        mp.validate(&layer, &cfg).unwrap();
    }

    // -- conv canonical mapping + compressed distillation --------------------

    fn tiny_conv_layer() -> QuantLayer {
        let spec = crate::snn::ConvSpec {
            in_channels: 2,
            in_h: 5,
            in_w: 5,
            out_channels: 3,
            kernel_h: 3,
            kernel_w: 3,
            stride: 1,
            padding: 1,
        };
        let mut rng = Rng::new(33);
        let mut kernel = vec![0i8; spec.kernel_len()];
        for w in kernel.iter_mut() {
            if !rng.bernoulli(0.3) {
                *w = rng.range_inclusive(-127, 127) as i8;
            }
        }
        QuantLayer::conv2d(spec, kernel, 0.01, LifParams::default()).unwrap()
    }

    #[test]
    fn conv_mapping_is_canonical_for_both_representations() {
        let compressed = tiny_conv_layer();
        let expanded = compressed.expand_conv().unwrap();
        let cfg = small_cfg(4, 8); // capacity 32 < out_dim 75 → 3 rounds
        for strat in [Strategy::IlpFlow, Strategy::Greedy, Strategy::RoundRobin] {
            let a = map_layer(&compressed, &cfg, strat).unwrap();
            let b = map_layer(&expanded, &cfg, strat).unwrap();
            a.validate(&compressed, &cfg).unwrap();
            b.validate(&expanded, &cfg).unwrap();
            assert_eq!(a.rounds.len(), compressed.out_dim.div_ceil(32));
            assert_eq!(a.rounds.len(), b.rounds.len());
            for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
                assert_eq!(ra.slot_of, rb.slot_of, "both representations must map alike");
            }
            assert_eq!(a.assigned_count(), compressed.out_dim, "dead dsts included");
        }
    }

    #[test]
    fn conv_canonical_validate_rejects_repacking() {
        let layer = tiny_conv_layer();
        let cfg = small_cfg(4, 8);
        let mut mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        // Swap two destinations' slots: structurally fine for an MLP,
        // but breaks the arithmetic slot derivation the generator uses.
        let (&i0, &s0) = mp.rounds[0].slot_of.iter().next().unwrap();
        let (&i1, &s1) = mp.rounds[0].slot_of.iter().nth(1).unwrap();
        mp.rounds[0].slot_of.insert(i0, s1);
        mp.rounds[0].slot_of.insert(i1, s0);
        assert!(mp.validate(&layer, &cfg).is_err());
    }

    #[test]
    fn conv_distill_stores_kernel_once() {
        let compressed = tiny_conv_layer();
        let expanded = compressed.expand_conv().unwrap();
        let cfg = small_cfg(4, 8);
        let mp = map_layer(&compressed, &cfg, Strategy::IlpFlow).unwrap();
        let img_c = distill(&compressed, &mp, &cfg).unwrap();
        let img_e = distill(&expanded, &mp, &cfg).unwrap();
        // Compressed image: kernel in weight SRAM, no row tables.
        assert_eq!(img_c.conv, compressed.conv);
        assert_eq!(img_c.weight_mem, compressed.kernel);
        for r in &img_c.rounds {
            assert!(r.e2a.is_empty() && r.sn_rows.is_empty());
        }
        // Oracle image: CSR-materialized, one weight per synapse.
        assert_eq!(img_e.conv, None);
        assert_eq!(img_e.weight_mem.len(), expanded.nnz());
        assert!(img_c.weight_mem.len() < img_e.weight_mem.len());
        // Same canonical mapping ⇒ identical residents (sweeps, reloads,
        // and fire ops price identically on both paths).
        for (rc, re) in img_c.rounds.iter().zip(&img_e.rounds) {
            assert_eq!(rc.residents, re.residents);
        }
        // Kernel must fit the weight SRAM.
        let mut tiny = cfg.clone();
        tiny.weight_mem_bytes = 4;
        assert!(distill(&compressed, &mp, &tiny).is_err());
    }

    // -- shard partitioner ---------------------------------------------------

    /// Network with fully dense layers of the given widths (deterministic
    /// cut costs: `costs[b] = sizes[b+1] + sizes[b+1]·sizes[b+2]`).
    fn dense_net(sizes: &[usize]) -> QuantNetwork {
        let layers = sizes
            .windows(2)
            .map(|w| {
                QuantLayer::new(w[0], w[1], vec![1i8; w[0] * w[1]], 0.1, LifParams::default())
                    .unwrap()
            })
            .collect();
        QuantNetwork { name: "dense".into(), layers, timesteps: 4 }
    }

    fn limits(max_layers: usize, budget: Option<usize>) -> ShardLimits {
        ShardLimits {
            max_layers_per_shard: max_layers,
            chip_weight_budget: budget,
            weight_bits: 8,
        }
    }

    #[test]
    fn cut_costs_price_boundary_width_and_fanout() {
        let net = dense_net(&[2, 1, 8, 8, 1]);
        // costs[b] = out_dim(b) + nnz(b+1)
        assert_eq!(shard_cut_costs(&net), vec![1 + 8, 8 + 64, 8 + 8]);
        assert_eq!(layer_weight_bytes(&net, 8), vec![2, 8, 64, 8]);
    }

    /// The satellite fix: weight bytes are bit-packed at the quantized
    /// width, not "one byte per nnz" regardless of `weight_bits`.
    #[test]
    fn layer_weight_bytes_packs_quantized_width() {
        // Layer with 3 non-zeros: 3·4 bits = 12 bits → 2 bytes, not 3.
        let l = QuantLayer::new(3, 1, vec![1, 2, 3], 0.1, LifParams::default()).unwrap();
        let net = QuantNetwork { name: "p".into(), layers: vec![l], timesteps: 1 };
        assert_eq!(layer_weight_bytes(&net, 8), vec![3]);
        assert_eq!(layer_weight_bytes(&net, 4), vec![2]);
        assert_eq!(layer_weight_bytes(&net, 16), vec![6]);
        assert_eq!(layer_weight_bytes(&net, 1), vec![1]);
        // Dense 8×8 at 4 bits: 64 weights → 32 bytes.
        let net = dense_net(&[8, 8]);
        assert_eq!(layer_weight_bytes(&net, 4), vec![32]);
    }

    #[test]
    fn dp_picks_cheapest_cut_when_unconstrained() {
        let net = dense_net(&[2, 1, 8, 8, 1]); // costs [9, 72, 16]
        let plan = partition_layers(&net, 2, &limits(4, None)).unwrap();
        assert_eq!(plan.cuts(), vec![0], "should cut the cheapest boundary");
        assert_eq!(plan.cut_cost, 9);
        assert_eq!(plan.shard_of, vec![0, 1, 1, 1]);
        plan.validate(&net, &limits(4, None)).unwrap();
    }

    /// The acceptance-criteria capacity test: with only 2 cores per chip
    /// the traffic-optimal 1+3 split is infeasible and the partitioner
    /// must take the more expensive balanced cut instead.
    #[test]
    fn partitioner_respects_per_chip_core_capacity() {
        let net = dense_net(&[2, 1, 8, 8, 1]); // costs [9, 72, 16]
        let lim = limits(2, None);
        for plan in [
            partition_layers(&net, 2, &lim).unwrap(),
            partition_layers_ilp(&net, 2, &lim).unwrap(),
        ] {
            assert_eq!(plan.cuts(), vec![1], "capacity must force the 2+2 split");
            assert_eq!(plan.cut_cost, 72);
            for r in plan.ranges() {
                assert!(r.len() <= 2);
            }
            plan.validate(&net, &lim).unwrap();
        }
    }

    /// Same forcing via the per-chip weight budget: layer 2 is heavy (64
    /// bytes), so a budget of 72 forbids co-locating it with both
    /// neighbours even though cores would allow it.
    #[test]
    fn partitioner_respects_chip_weight_budget() {
        let net = dense_net(&[2, 1, 8, 8, 1]); // weights [2, 8, 64, 8]
        let lim = limits(4, Some(72));
        let dp = partition_layers(&net, 2, &lim).unwrap();
        let ilp = partition_layers_ilp(&net, 2, &lim).unwrap();
        assert_eq!(dp.cut_cost, ilp.cut_cost);
        for plan in [dp, ilp] {
            let weights = layer_weight_bytes(&net, 8);
            for r in plan.ranges() {
                assert!(weights[r].iter().sum::<usize>() <= 72);
            }
            plan.validate(&net, &lim).unwrap();
        }
        // A budget smaller than the heaviest layer is infeasible outright.
        assert!(partition_layers(&net, 2, &limits(4, Some(10))).is_err());
        assert!(partition_layers_ilp(&net, 2, &limits(4, Some(10))).is_err());
    }

    /// The DP and the explicit ILP are the same optimizer: equal optimal
    /// cost (and both valid) across randomized networks, shard counts,
    /// and capacity limits.
    #[test]
    fn dp_and_ilp_partitioners_agree() {
        for seed in 0..6u64 {
            let mut rng = Rng::new(seed);
            let n_layers = 3 + rng.below(4); // 3..=6
            let mut sizes = vec![4 + rng.below(12)];
            for _ in 0..n_layers {
                sizes.push(2 + rng.below(10));
            }
            let mcfg = crate::config::ModelConfig {
                name: "p".into(),
                layer_sizes: sizes,
                timesteps: 3,
                beta: 0.9,
                v_threshold: 1.0,
                v_reset: 0.0,
            };
            let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
            let lim = limits(1 + rng.below(3), None);
            for k in 1..=net.layers.len() {
                let dp = partition_layers(&net, k, &lim);
                let ilp = partition_layers_ilp(&net, k, &lim);
                match (dp, ilp) {
                    (Ok(a), Ok(b)) => {
                        assert_eq!(a.cut_cost, b.cut_cost, "seed {seed} k {k}");
                        a.validate(&net, &lim).unwrap();
                        b.validate(&net, &lim).unwrap();
                    }
                    (Err(_), Err(_)) => {} // both infeasible (capacity)
                    (a, b) => panic!("seed {seed} k {k}: DP {a:?} vs ILP {b:?} disagree"),
                }
            }
        }
    }

    #[test]
    fn partitioner_edge_cases_and_validation() {
        let net = dense_net(&[3, 4, 5, 2]);
        let lim = limits(4, None);
        // 1 shard: no cuts, zero cost.
        let one = partition_layers(&net, 1, &lim).unwrap();
        assert_eq!(one, ShardPlan::monolithic(3));
        // shards == layers: every boundary cut.
        let all = partition_layers(&net, 3, &lim).unwrap();
        assert_eq!(all.cuts(), vec![0, 1]);
        assert_eq!(all.cut_cost, shard_cut_costs(&net).iter().sum::<u64>());
        // shards > layers / zero shards: errors.
        assert!(partition_layers(&net, 4, &lim).is_err());
        assert!(partition_layers(&net, 0, &lim).is_err());
        // validate() rejects structural breakage.
        let mut broken = all.clone();
        broken.shard_of = vec![0, 2, 1];
        assert!(broken.validate(&net, &lim).is_err());
        let mut wrong_cost = partition_layers(&net, 2, &lim).unwrap();
        wrong_cost.cut_cost += 1;
        assert!(wrong_cost.validate(&net, &lim).is_err());
        let mut over = partition_layers(&net, 2, &lim).unwrap();
        assert!(over.validate(&net, &limits(1, None)).is_err(), "{over:?} over capacity");
        over.num_shards = 3;
        assert!(over.validate(&net, &lim).is_err(), "empty shard accepted");
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(Strategy::parse("ilp").unwrap(), Strategy::IlpFlow);
        assert_eq!(Strategy::parse("greedy").unwrap(), Strategy::Greedy);
        assert!(Strategy::parse("bogus").is_err());
    }
}
