//! Synthetic event-stream datasets.
//!
//! The paper evaluates on N-MNIST (saccade-converted MNIST, 34×34×2) and
//! CIFAR10-DVS (DVS-recorded CIFAR10, 128×128×2). Neither is available in
//! this environment, so we generate *statistically matched* synthetic
//! stand-ins (see DESIGN.md §2 for the substitution argument):
//!
//! * **N-MNIST-like** — ten seven-segment-style digit templates rendered on
//!   a 34×34 grid, swept through the three-saccade motion of the original
//!   recording rig; edge polarity drives the ON/OFF channels; per-pixel
//!   Poisson event noise. Low activity (≈1–3% of pixels per step).
//! * **CIFAR10-DVS-like** — ten class-conditional oriented-grating texture
//!   templates on a 128×128 grid with jittered drift, markedly higher event
//!   rates (the paper's Figs. 6–7 hinge on CIFAR10-DVS ≫ N-MNIST activity).
//!
//! Both generators are deterministic given `(seed, class, index)`, so the
//! python training pipeline and the rust simulator can generate identical
//! splits without shipping data files.

use crate::snn::SpikeTrain;
use crate::util::rng::Rng;

/// Which synthetic dataset to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 34×34×2 = 2312 inputs, 10 classes, sparse.
    NMnist,
    /// 128×128×2 = 32768 inputs, 10 classes, dense.
    Cifar10Dvs,
    /// 32×32×2 = 2048 inputs, 10 classes — the scaled-down CIFAR10-DVS
    /// used by quick tests (`ModelConfig::cifar10dvs_mlp_small`).
    Cifar10DvsSmall,
}

impl DatasetKind {
    pub fn side(&self) -> usize {
        match self {
            DatasetKind::NMnist => 34,
            DatasetKind::Cifar10Dvs => 128,
            DatasetKind::Cifar10DvsSmall => 32,
        }
    }

    /// Input dimensionality (side² × 2 polarity channels).
    pub fn input_dim(&self) -> usize {
        self.side() * self.side() * 2
    }

    pub fn num_classes(&self) -> usize {
        10
    }

    /// Baseline per-pixel event probability per step (noise floor).
    fn noise_rate(&self) -> f64 {
        match self {
            DatasetKind::NMnist => 0.0015,
            DatasetKind::Cifar10Dvs => 0.004,
            DatasetKind::Cifar10DvsSmall => 0.004,
        }
    }

    /// Peak per-pixel event probability on active template pixels.
    fn signal_rate(&self) -> f64 {
        match self {
            DatasetKind::NMnist => 0.35,
            DatasetKind::Cifar10Dvs => 0.55,
            DatasetKind::Cifar10DvsSmall => 0.55,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::NMnist => "nmnist_syn",
            DatasetKind::Cifar10Dvs => "cifar10dvs_syn",
            DatasetKind::Cifar10DvsSmall => "cifar10dvs_small_syn",
        }
    }
}

/// One labelled event-stream sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub label: usize,
    pub events: SpikeTrain,
}

/// Deterministic synthetic event dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub seed: u64,
    pub timesteps: usize,
}

impl Dataset {
    pub fn new(kind: DatasetKind, seed: u64, timesteps: usize) -> Self {
        Self { kind, seed, timesteps }
    }

    /// Generate sample `index` of class `label` (deterministic).
    pub fn sample(&self, label: usize, index: u64) -> Sample {
        assert!(label < self.kind.num_classes());
        let mut rng = Rng::new(
            self.seed ^ (label as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ index.wrapping_mul(0xD134_2543_DE82_EF95),
        );
        let events = match self.kind {
            DatasetKind::NMnist => self.gen_nmnist(label, &mut rng),
            DatasetKind::Cifar10Dvs | DatasetKind::Cifar10DvsSmall => {
                self.gen_dvs_texture(label, &mut rng)
            }
        };
        Sample { label, events }
    }

    /// Generate `n` samples with round-robin labels (a balanced split).
    pub fn balanced_split(&self, n: usize, index_offset: u64) -> Vec<Sample> {
        (0..n)
            .map(|i| {
                self.sample(i % self.kind.num_classes(), index_offset + (i / 10) as u64)
            })
            .collect()
    }

    // -- N-MNIST-like ------------------------------------------------------

    /// Seven-segment digit template on the 34×34 grid: returns per-pixel
    /// intensity in [0,1] (1 = on-stroke).
    fn digit_template(label: usize, side: usize) -> Vec<f32> {
        // Segment layout (classic seven-segment):
        //   _a_
        //  f| |b
        //   -g-
        //  e| |c
        //   _d_
        const SEGMENTS: [[bool; 7]; 10] = [
            // a      b      c      d      e      f      g
            [true, true, true, true, true, true, false],   // 0
            [false, true, true, false, false, false, false], // 1
            [true, true, false, true, true, false, true],  // 2
            [true, true, true, true, false, false, true],  // 3
            [false, true, true, false, false, true, true], // 4
            [true, false, true, true, false, true, true],  // 5
            [true, false, true, true, true, true, true],   // 6
            [true, true, true, false, false, false, false], // 7
            [true, true, true, true, true, true, true],    // 8
            [true, true, true, true, false, true, true],   // 9
        ];
        let mut img = vec![0.0f32; side * side];
        let segs = SEGMENTS[label];
        // Digit body occupies a centered box.
        let x0 = side / 4;
        let x1 = side - side / 4 - 1;
        let y0 = side / 6;
        let y1 = side - side / 6 - 1;
        let ym = (y0 + y1) / 2;
        let w = 2usize; // stroke half-width
        let hline = |y: usize, img: &mut Vec<f32>| {
            for x in x0..=x1 {
                for dy in 0..w {
                    let yy = (y + dy).min(side - 1);
                    img[yy * side + x] = 1.0;
                }
            }
        };
        let vline = |x: usize, ya: usize, yb: usize, img: &mut Vec<f32>| {
            for y in ya..=yb {
                for dx in 0..w {
                    let xx = (x + dx).min(side - 1);
                    img[y * side + xx] = 1.0;
                }
            }
        };
        if segs[0] {
            hline(y0, &mut img);
        }
        if segs[3] {
            hline(y1 - w + 1, &mut img);
        }
        if segs[6] {
            hline(ym, &mut img);
        }
        if segs[5] {
            vline(x0, y0, ym, &mut img);
        }
        if segs[1] {
            vline(x1 - w + 1, y0, ym, &mut img);
        }
        if segs[4] {
            vline(x0, ym, y1, &mut img);
        }
        if segs[2] {
            vline(x1 - w + 1, ym, y1, &mut img);
        }
        img
    }

    fn gen_nmnist(&self, label: usize, rng: &mut Rng) -> SpikeTrain {
        let side = self.kind.side();
        let dim = self.kind.input_dim();
        let template = Self::digit_template(label, side);
        let mut st = SpikeTrain::new(dim, self.timesteps);

        // Three saccades (as in the original N-MNIST recording): the sensor
        // moves along three directions, one per third of the recording. The
        // moving edge generates ON events on the leading edge and OFF events
        // on the trailing edge.
        let saccades = [(1i32, 0i32), (0, 1), (-1, -1)];
        let per_phase = (self.timesteps + 2) / 3;
        let noise = self.kind.noise_rate();
        let signal = self.kind.signal_rate();

        for t in 0..self.timesteps {
            let phase = (t / per_phase.max(1)).min(2);
            let (dx, dy) = saccades[phase];
            let tp = (t % per_phase.max(1)) as i32 - (per_phase as i32) / 2;
            let (ox, oy) = (dx * tp / 3, dy * tp / 3);
            let spikes = &mut st.spikes[t];
            for y in 0..side {
                for x in 0..side {
                    // Sample template at shifted position; the *gradient*
                    // along the motion direction decides polarity.
                    let sx = x as i32 - ox;
                    let sy = y as i32 - oy;
                    let here = sample2d(&template, side, sx, sy);
                    let ahead = sample2d(&template, side, sx - dx, sy - dy);
                    let diff = here - ahead;
                    let base = y * side + x;
                    // ON channel (index base), OFF channel (base + side²).
                    let p_on = noise + signal * diff.max(0.0) as f64 + 0.03 * here as f64;
                    let p_off = noise + signal * (-diff).max(0.0) as f64;
                    if rng.bernoulli(p_on.min(0.95)) {
                        spikes.push(base as u32);
                    }
                    if rng.bernoulli(p_off.min(0.95)) {
                        spikes.push((base + side * side) as u32);
                    }
                }
            }
            spikes.sort_unstable();
            spikes.dedup();
        }
        st
    }

    // -- CIFAR10-DVS-like ---------------------------------------------------

    /// Oriented-grating texture: class controls orientation & spatial
    /// frequency; a second harmonic varies with class parity so classes are
    /// not linearly ordered.
    fn gen_dvs_texture(&self, label: usize, rng: &mut Rng) -> SpikeTrain {
        let side = self.kind.side();
        let dim = self.kind.input_dim();
        let mut st = SpikeTrain::new(dim, self.timesteps);

        let angle = label as f32 * std::f32::consts::PI / 10.0;
        let freq = 2.0 + (label % 5) as f32 * 1.5;
        let harmonic = if label % 2 == 0 { 2.0 } else { 3.0 };
        let (c, s) = (angle.cos(), angle.sin());
        let noise = self.kind.noise_rate();
        let signal = self.kind.signal_rate();
        // Per-sample drift velocity (recorded objects jitter on the DVS).
        let vx = rng.uniform(-1.5, 1.5) as f32;
        let vy = rng.uniform(-1.5, 1.5) as f32;
        let phase0 = rng.uniform(0.0, std::f64::consts::TAU) as f32;

        for t in 0..self.timesteps {
            let tt = t as f32;
            let spikes = &mut st.spikes[t];
            for y in 0..side {
                for x in 0..side {
                    let xf = (x as f32 + vx * tt) / side as f32;
                    let yf = (y as f32 + vy * tt) / side as f32;
                    let u = c * xf + s * yf;
                    let v = -s * xf + c * yf;
                    let g = (std::f32::consts::TAU * freq * u + phase0).sin()
                        + 0.5 * (std::f32::consts::TAU * freq * harmonic * v).sin();
                    // Temporal derivative of the drifting grating creates
                    // the events; magnitude ∝ |gradient·velocity|.
                    let g_next = (std::f32::consts::TAU
                        * freq
                        * (c * (xf + vx / side as f32) + s * (yf + vy / side as f32))
                        + phase0)
                        .sin()
                        + 0.5
                            * (std::f32::consts::TAU
                                * freq
                                * harmonic
                                * (-s * (xf + vx / side as f32) + c * (yf + vy / side as f32)))
                                .sin();
                    let d = g_next - g;
                    let base = y * side + x;
                    let p_on = noise + signal * d.max(0.0) as f64;
                    let p_off = noise + signal * (-d).max(0.0) as f64;
                    if rng.bernoulli(p_on.min(0.95)) {
                        spikes.push(base as u32);
                    }
                    if rng.bernoulli(p_off.min(0.95)) {
                        spikes.push((base + side * side) as u32);
                    }
                }
            }
            spikes.sort_unstable();
            spikes.dedup();
        }
        st
    }
}

#[inline]
fn sample2d(img: &[f32], side: usize, x: i32, y: i32) -> f32 {
    if x < 0 || y < 0 || x >= side as i32 || y >= side as i32 {
        0.0
    } else {
        img[y as usize * side + x as usize]
    }
}

/// Dataset-level statistics used for calibration tests and DESIGN.md.
#[derive(Debug, Clone, Default)]
pub struct DatasetStats {
    pub mean_rate: f64,
    pub mean_events_per_step: f64,
    pub max_events_per_step: usize,
}

/// Compute statistics over `n` samples.
pub fn stats(ds: &Dataset, n: usize) -> DatasetStats {
    let mut total_rate = 0.0;
    let mut total_per_step = 0.0;
    let mut max_per_step = 0usize;
    let mut count = 0usize;
    for s in ds.balanced_split(n, 0) {
        total_rate += s.events.rate();
        for step in &s.events.spikes {
            total_per_step += step.len() as f64;
            max_per_step = max_per_step.max(step.len());
            count += 1;
        }
    }
    DatasetStats {
        mean_rate: total_rate / n as f64,
        mean_events_per_step: total_per_step / count.max(1) as f64,
        max_events_per_step: max_per_step,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_models() {
        assert_eq!(DatasetKind::NMnist.input_dim(), 2312);
        assert_eq!(DatasetKind::Cifar10Dvs.input_dim(), 32768);
        assert_eq!(DatasetKind::Cifar10DvsSmall.input_dim(), 2048);
    }

    #[test]
    fn samples_are_deterministic() {
        let ds = Dataset::new(DatasetKind::NMnist, 7, 10);
        let a = ds.sample(3, 0);
        let b = ds.sample(3, 0);
        assert_eq!(a.events, b.events);
        let c = ds.sample(3, 1);
        assert_ne!(a.events, c.events, "different index must differ");
        let d = ds.sample(4, 0);
        assert_ne!(a.events, d.events, "different class must differ");
    }

    #[test]
    fn samples_are_valid_spike_trains() {
        for kind in [DatasetKind::NMnist, DatasetKind::Cifar10DvsSmall] {
            let ds = Dataset::new(kind, 1, 6);
            for label in 0..10 {
                let s = ds.sample(label, 0);
                s.events.validate().unwrap();
                assert_eq!(s.events.num_neurons, kind.input_dim());
                assert_eq!(s.label, label);
            }
        }
    }

    #[test]
    fn nmnist_sparser_than_cifar() {
        // The paper's Figures 6–7 rest on CIFAR10-DVS having much higher
        // spike activity than N-MNIST; the generators must preserve that.
        let nm = stats(&Dataset::new(DatasetKind::NMnist, 3, 10), 10);
        let cf = stats(&Dataset::new(DatasetKind::Cifar10DvsSmall, 3, 10), 10);
        assert!(
            cf.mean_rate > 2.0 * nm.mean_rate,
            "cifar rate {} should dwarf nmnist rate {}",
            cf.mean_rate,
            nm.mean_rate
        );
        // Both stay plausibly sparse (well under 50% of pixels firing).
        assert!(nm.mean_rate < 0.2, "{}", nm.mean_rate);
        assert!(cf.mean_rate < 0.5, "{}", cf.mean_rate);
        assert!(nm.mean_rate > 0.001, "nmnist must not be dead: {}", nm.mean_rate);
    }

    #[test]
    fn digit_templates_are_distinct() {
        let t: Vec<Vec<f32>> =
            (0..10).map(|l| Dataset::digit_template(l, 34)).collect();
        for i in 0..10 {
            for j in (i + 1)..10 {
                let diff: f32 = t[i]
                    .iter()
                    .zip(&t[j])
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                assert!(diff > 10.0, "templates {i} and {j} nearly identical");
            }
        }
        // Template 8 (all segments) strictly covers template 1 (b+c only).
        let on8: f32 = t[8].iter().sum();
        let on1: f32 = t[1].iter().sum();
        assert!(on8 > on1);
    }

    #[test]
    fn balanced_split_is_balanced() {
        let ds = Dataset::new(DatasetKind::NMnist, 1, 4);
        let split = ds.balanced_split(30, 0);
        assert_eq!(split.len(), 30);
        for c in 0..10 {
            assert_eq!(split.iter().filter(|s| s.label == c).count(), 3);
        }
    }

    #[test]
    fn classes_statistically_separable() {
        // Per-class mean event maps must differ enough for a classifier to
        // have signal: compare event-count vectors between two classes.
        let ds = Dataset::new(DatasetKind::NMnist, 11, 12);
        let acc_counts = |label: usize| -> Vec<f64> {
            let mut acc = vec![0.0f64; DatasetKind::NMnist.input_dim()];
            for i in 0..4 {
                let counts = ds.sample(label, i).events.counts();
                for (a, c) in acc.iter_mut().zip(counts) {
                    *a += c as f64;
                }
            }
            acc
        };
        let c0 = acc_counts(0);
        let c1 = acc_counts(1);
        let dot: f64 = c0.iter().zip(&c1).map(|(a, b)| a * b).sum();
        let n0: f64 = c0.iter().map(|a| a * a).sum::<f64>().sqrt();
        let n1: f64 = c1.iter().map(|a| a * a).sum::<f64>().sqrt();
        let cos = dot / (n0 * n1);
        assert!(cos < 0.95, "class event maps too similar: cos={cos}");
    }
}
