//! `menage` — CLI for the MENAGE accelerator reproduction.
//!
//! Subcommands (clap is not in the offline vendor set; args are parsed by
//! the in-tree parser below):
//!
//! ```text
//! menage simulate  --model nmnist --accel accel1 [--samples N] [--workers W]
//!                  [--strategy ilp_flow|greedy|first_fit|round_robin]
//!                  [--analog ideal|paper] [--golden] [--synthetic]
//! menage map       --model nmnist --accel accel1 [--strategy S]
//! menage waveform  [--out waveform.json]
//! menage info      --model nmnist
//! ```
//!
//! `simulate` is the end-to-end driver: load the python-trained weights
//! (or generate a synthetic network with `--synthetic`), ILP-map onto the
//! accelerator, run the eval split through the cycle-accurate simulator
//! via the multi-worker coordinator, and report accuracy, cycles, and
//! TOPS/W. `--golden` additionally loads the JAX-lowered HLO through PJRT
//! and cross-checks predictions.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use menage::accel::{Menage, RunOutput};
use menage::analog::AnalogParams;
use menage::bench::{emit_json_file, Table};
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::datasets::{Dataset, DatasetKind};
use menage::energy::{report, EnergyModel};
use menage::fault::{FaultPlan, SystemChaos};
use menage::mapping::{map_network, Strategy};
use menage::runtime::{artifacts_dir, cpu_client, pjrt_available, GoldenModel};
use menage::serve::protocol::NO_ID;
use menage::serve::{
    Client, ErrorCode, RemoteShardConfig, RemoteShardPipeline, Reply, ServeConfig, Server,
    ShardHostConfig, ShardHostServer,
};
use menage::shard::ShardedMenage;
use menage::snn::{ConvSpec, QuantNetwork, SpikeTrain};
use menage::trace::MemoryTrace;
use menage::util::json::Json;
use menage::util::rng::Rng;
use menage::util::stats::Quantiles;
use menage::util::tensorfile::TensorFile;

/// Minimal `--key value` / `--flag` argument parser.
struct Args {
    cmd: String,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse() -> Result<Self> {
        Self::parse_from(std::env::args().skip(1).collect())
    }

    fn parse_from(argv: Vec<String>) -> Result<Self> {
        let mut it = argv.into_iter();
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = BTreeMap::new();
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --option, got {a:?}"))?;
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(key.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Self { cmd, kv, flags })
    }

    /// Validate against the subcommand's full option vocabulary: any
    /// parsed option or flag outside it is an error, so a typo'd flag
    /// fails loudly instead of silently falling back to a default.
    fn expect_known(&self, keys: &[&str], flags: &[&str]) -> Result<()> {
        for k in self.kv.keys() {
            if !keys.contains(&k.as_str()) {
                bail!(
                    "unknown option --{k} for `{}` (valid options: {}; valid flags: {})",
                    self.cmd,
                    fmt_vocab(keys),
                    fmt_vocab(flags)
                );
            }
        }
        for f in &self.flags {
            if !flags.contains(&f.as_str()) {
                // A value-less occurrence of a valid *option* (e.g. a
                // trailing `--samples`) is also a usage error, with a more
                // specific message.
                if keys.contains(&f.as_str()) {
                    bail!("option --{f} requires a value");
                }
                bail!(
                    "unknown flag --{f} for `{}` (valid options: {}; valid flags: {})",
                    self.cmd,
                    fmt_vocab(keys),
                    fmt_vocab(flags)
                );
            }
        }
        Ok(())
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

fn fmt_vocab(words: &[&str]) -> String {
    if words.is_empty() {
        "(none)".to_string()
    } else {
        words
            .iter()
            .map(|w| format!("--{w}"))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Resolve a model name to its config + dataset kind + artifact base name.
fn resolve_model(name: &str) -> Result<(ModelConfig, DatasetKind, &'static str)> {
    Ok(match name {
        "nmnist" => (ModelConfig::nmnist_mlp(), DatasetKind::NMnist, "nmnist"),
        "cifar_small" | "cifar10dvs_small" => (
            ModelConfig::cifar10dvs_mlp_small(),
            DatasetKind::Cifar10DvsSmall,
            "cifar_small",
        ),
        "cifar" | "cifar10dvs" => {
            (ModelConfig::cifar10dvs_mlp(), DatasetKind::Cifar10Dvs, "cifar")
        }
        "cifar_conv" | "cifar10dvs_conv" => {
            // Compressed conv stack over the 2×32×32 event frame; the
            // layer_sizes here are the layer *dimensions* (the dense proxy
            // view used for display and capacity reporting — the actual
            // weights are one kernel per conv layer).
            let specs = cifar_conv_specs();
            let mut sizes = vec![specs[0].in_dim()];
            sizes.extend(specs.iter().map(|s| s.out_dim()));
            sizes.push(10);
            let mcfg = ModelConfig {
                name: "cifar10dvs_conv".into(),
                layer_sizes: sizes,
                timesteps: 20,
                beta: 0.9,
                v_threshold: 1.0,
                v_reset: 0.0,
            };
            (mcfg, DatasetKind::Cifar10DvsSmall, "cifar_conv")
        }
        _ => bail!("unknown model {name:?} (nmnist | cifar_small | cifar | cifar_conv)"),
    })
}

/// The CIFAR10-DVS conv stack (compressed synapses): 2×32×32 events →
/// 8×16×16 → 8×8×8, then a dense 10-class head.
fn cifar_conv_specs() -> Vec<ConvSpec> {
    vec![
        ConvSpec {
            in_channels: 2,
            in_h: 32,
            in_w: 32,
            out_channels: 8,
            kernel_h: 3,
            kernel_w: 3,
            stride: 2,
            padding: 1,
        },
        ConvSpec {
            in_channels: 8,
            in_h: 16,
            in_w: 16,
            out_channels: 8,
            kernel_h: 3,
            kernel_w: 3,
            stride: 2,
            padding: 1,
        },
    ]
}

/// Apply `--expand-conv`: densify every compressed conv layer into its
/// expanded synapse table (the oracle representation — useful for A/B
/// footprint and shard-count comparisons against the same model).
fn maybe_expand_conv(net: QuantNetwork, args: &Args) -> Result<QuantNetwork> {
    if args.has("expand-conv") && net.has_compressed() {
        return net.expand_convs();
    }
    Ok(net)
}

fn resolve_accel(name: &str) -> Result<AcceleratorConfig> {
    Ok(match name {
        "accel1" => AcceleratorConfig::accel1(),
        "accel2" => AcceleratorConfig::accel2(),
        path => AcceleratorConfig::from_file(path)
            .with_context(|| format!("--accel {path:?} is neither a preset nor a config file"))?,
    })
}

fn resolve_analog(args: &Args) -> Result<AnalogParams> {
    Ok(match args.get_or("analog", "ideal").as_str() {
        "ideal" => AnalogParams::ideal(),
        "paper" => AnalogParams::paper(),
        other => bail!("--analog must be ideal|paper, got {other:?}"),
    })
}

/// Load the trained network from artifacts, or synthesize one.
fn load_network(base: &str, mcfg: &ModelConfig, synthetic: bool) -> Result<QuantNetwork> {
    if synthetic {
        let mut rng = Rng::new(7);
        if base == "cifar_conv" {
            return QuantNetwork::random_conv(
                &mcfg.name,
                &cifar_conv_specs(),
                10,
                mcfg.timesteps,
                0.5,
                &mut rng,
            );
        }
        return Ok(QuantNetwork::random(mcfg, 0.5, &mut rng));
    }
    let path = artifacts_dir().join(format!("{base}.weights.mtz"));
    let tf = TensorFile::load(&path).with_context(|| {
        format!(
            "loading {} — run `make artifacts` first or pass --synthetic",
            path.display()
        )
    })?;
    QuantNetwork::from_tensorfile(base, &tf)
}

/// Load the eval split exported by aot.py: (inputs, labels, golden counts).
fn load_eval(base: &str, limit: usize) -> Result<Vec<(SpikeTrain, usize, Vec<f32>)>> {
    let path = artifacts_dir().join(format!("{base}.eval.mtz"));
    let tf = TensorFile::load(&path)?;
    let ev = tf.get("events")?;
    let dims = ev.dims().to_vec(); // [n, T, dim]
    if dims.len() != 3 {
        bail!("events tensor must be 3-D");
    }
    let data = ev.as_u8()?;
    let labels = tf.get("labels")?.as_i32()?;
    let golden = tf.get("golden_counts")?.as_f32()?;
    let (n, t, d) = (dims[0].min(limit), dims[1], dims[2]);
    let classes = golden.len() / dims[0];
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut st = SpikeTrain::new(d, t);
        for (ti, step) in st.spikes.iter_mut().enumerate() {
            let row = &data[i * t * d + ti * d..i * t * d + (ti + 1) * d];
            for (j, &v) in row.iter().enumerate() {
                if v != 0 {
                    step.push(j as u32);
                }
            }
        }
        out.push((
            st,
            labels[i] as usize,
            golden[i * classes..(i + 1) * classes].to_vec(),
        ));
    }
    Ok(out)
}

fn cmd_info(args: &Args) -> Result<()> {
    args.expect_known(&["model"], &[])?;
    let (mcfg, kind, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    println!("model: {}", mcfg.name);
    println!("  layers:     {:?}", mcfg.layer_sizes);
    println!("  params:     {}", mcfg.num_params());
    println!("  timesteps:  {}", mcfg.timesteps);
    println!("  dataset:    {} (input dim {})", kind.name(), kind.input_dim());
    if let Ok(net) = load_network(base, &mcfg, false) {
        println!("  trained artifact: {} nnz / sparsity {:.2}", net.nnz(), net.sparsity());
    } else {
        println!("  trained artifact: not found (run `make artifacts`)");
    }
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    args.expect_known(&["model", "accel", "strategy"], &["synthetic", "expand-conv"])?;
    let (mcfg, _, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    let cfg = resolve_accel(&args.get_or("accel", "accel1"))?;
    let strategy = Strategy::parse(&args.get_or("strategy", "ilp_flow"))?;
    let net = maybe_expand_conv(load_network(base, &mcfg, args.has("synthetic"))?, args)?;
    let t0 = std::time::Instant::now();
    let mappings = map_network(&net, &cfg, strategy)?;
    let dt = t0.elapsed();
    let mut table = Table::new(
        format!("{} on {} via {}", net.name, cfg.name, strategy.name()),
        &["layer", "neurons", "rounds", "assigned", "unassigned", "peak load"],
    );
    for (l, (mp, layer)) in mappings.iter().zip(&net.layers).enumerate() {
        mp.validate(layer, &cfg)?;
        table.row(&[
            l.to_string(),
            layer.out_dim.to_string(),
            mp.rounds.len().to_string(),
            mp.assigned_count().to_string(),
            mp.unassigned.len().to_string(),
            mp.peak_engine_load(layer, cfg.a_neurons_per_core).to_string(),
        ]);
    }
    table.print();
    println!("mapping time: {dt:?}");
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    args.expect_known(
        &[
            "model",
            "accel",
            "strategy",
            "analog",
            "workers",
            "samples",
            "shards",
            "out",
            "faults",
            "remote-shards",
            "remote-window",
        ],
        &["golden", "synthetic", "check-monolithic", "expand-conv"],
    )?;
    if let Some(spec) = args.get("remote-shards") {
        return cmd_simulate_remote(args, &spec.to_string());
    }
    if args.get("remote-window").is_some() {
        bail!("--remote-window only applies with --remote-shards");
    }
    let (mcfg, kind, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    let cfg = resolve_accel(&args.get_or("accel", "accel1"))?;
    let strategy = Strategy::parse(&args.get_or("strategy", "ilp_flow"))?;
    let analog = resolve_analog(args)?;
    let workers = args.get_usize("workers", 4)?;
    let samples = args.get_usize("samples", 40)?;
    let shards_req = args.get_usize("shards", 1)?.max(1);
    let check_mono = args.has("check-monolithic");
    let synthetic = args.has("synthetic");
    let fault_spec = args.get("faults").map(str::to_string);
    let fault_plan = match fault_spec.as_deref() {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::default(),
    };
    if !fault_plan.is_empty() && check_mono {
        // Stuck rows / dead slots / drift are deterministic, but transient
        // bit flips draw from per-chip RNG streams that advance with each
        // worker's own request subset — a single-chip replay cannot
        // reproduce the multi-worker draw order.
        bail!(
            "--check-monolithic cannot be combined with --faults: transient fault RNG \
             streams advance per worker, so a single-chip replay is not bit-comparable"
        );
    }

    let net = maybe_expand_conv(load_network(base, &mcfg, synthetic)?, args)?;
    if net.has_compressed() {
        println!(
            "compressed conv synapses: {} stored weights (dense expansion would store {})",
            net.stored_weights(),
            net.expand_convs()?.stored_weights()
        );
    }
    println!(
        "loaded {}: {} params, {} nnz (sparsity {:.2}), T={}",
        net.name,
        net.num_params(),
        net.nnz(),
        net.sparsity(),
        net.timesteps
    );
    let mut sharded = if shards_req > 1 {
        let s = ShardedMenage::build(&net, &cfg, strategy, &analog, 7, shards_req)?;
        println!(
            "sharded over {} chips (estimated cut traffic {}):",
            s.num_shards(),
            s.plan.cut_cost
        );
        for (si, (range, chip)) in s.plan.ranges().iter().zip(&s.shards).enumerate() {
            println!(
                "  shard {si}: layers {}..{} on {} cores{}",
                range.start,
                range.end,
                chip.cores.len(),
                if si > 0 {
                    format!(", cut cost in {}", s.boundary_cost[si - 1])
                } else {
                    String::new()
                }
            );
        }
        Some(s)
    } else {
        None
    };
    // The monolithic chip: the execution backend when not sharding, the
    // cross-check oracle under --check-monolithic. A sharded run without
    // the check never builds it — sharding exists precisely for models
    // deeper than one chip.
    let mut mono = if sharded.is_none() || check_mono {
        Some(Menage::build(&net, &cfg, strategy, &analog, 7)?)
    } else {
        None
    };
    // Fault-free clones kept aside as the degradation oracle, taken
    // *before* faults are installed on the execution backend.
    let (mut oracle_mono, mut oracle_sharded) = if fault_plan.is_empty() {
        (None, None)
    } else {
        (mono.clone(), sharded.clone())
    };
    if !fault_plan.is_empty() {
        if let Some(s) = sharded.as_mut() {
            s.install_faults(&fault_plan);
        }
        if let Some(m) = mono.as_mut() {
            m.install_faults(&fault_plan);
        }
        println!(
            "injecting hardware faults: {} (seed {})",
            fault_spec.as_deref().unwrap_or("-"),
            fault_plan.seed
        );
    }
    if let Some(chip) = &mono {
        for (l, core) in chip.cores.iter().enumerate() {
            println!(
                "  core {l}: {} rounds, {} SN rows, {} weight bytes",
                core.rounds(),
                core.image_sn_rows(),
                core.weight_bytes()
            );
        }
    }

    // Inputs: trained eval split or synthetic events.
    let eval = if synthetic {
        let ds = Dataset::new(kind, 3, net.timesteps);
        ds.balanced_split(samples, 0)
            .into_iter()
            .map(|s| (s.events, s.label, vec![]))
            .collect()
    } else {
        load_eval(base, samples)?
    };
    println!("running {} samples on {} workers…", eval.len(), workers);

    let mut coord = match &sharded {
        Some(s) => Coordinator::sharded(s, workers),
        None => Coordinator::new(mono.as_ref().expect("mono built when not sharded"), workers),
    };
    let t0 = std::time::Instant::now();
    let batch: Vec<(SpikeTrain, Option<usize>)> = eval
        .iter()
        .map(|(st, label, _)| (st.clone(), Some(*label)))
        .collect();
    let responses = coord.run_batch(batch)?;
    let wall = t0.elapsed();

    // The smoke-shard gate: replay every input through a monolithic chip
    // and require the classifier train + modeled cycles the (possibly
    // sharded) coordinator returned to be bit-identical.
    if check_mono {
        let mut oracle = mono.clone().expect("mono built under --check-monolithic");
        let mut out = RunOutput::default();
        for ((st, _, _), resp) in eval.iter().zip(&responses) {
            oracle.run_into(st, &mut out)?;
            if resp.output != *out.output() {
                bail!(
                    "sharded-vs-monolithic mismatch: request {} classifier train diverges",
                    resp.id
                );
            }
            if resp.cycles != out.cycles {
                bail!(
                    "sharded-vs-monolithic mismatch: request {} cycles {} != {}",
                    resp.id,
                    resp.cycles,
                    out.cycles
                );
            }
        }
        println!(
            "sharded-vs-monolithic check: {} samples bit-identical (trains + cycles)",
            eval.len()
        );
    }

    // Optional golden cross-check through PJRT (skipped, not fatal, on a
    // build without the `pjrt` feature).
    let mut golden_agree = None;
    if args.has("golden") && !pjrt_available() {
        eprintln!("--golden skipped: built without the `pjrt` cargo feature");
    } else if args.has("golden") {
        let client = cpu_client()?;
        let hlo = artifacts_dir().join(format!("{base}.hlo.txt"));
        let gm = GoldenModel::load(
            &client,
            &hlo,
            net.timesteps,
            net.input_dim(),
            net.output_dim(),
        )?;
        let mut agree = 0usize;
        for ((st, _, _), resp) in eval.iter().zip(&responses) {
            if gm.predict(st)? == resp.predicted {
                agree += 1;
            }
        }
        golden_agree = Some(agree as f64 / eval.len() as f64);
    }

    let chips = coord.shutdown();
    // Merge stats from all workers into one report.
    let merged = merge_chips(chips)
        .ok_or_else(|| anyhow!("no worker chips survived the run; stats unavailable"))?;
    let model = EnergyModel::paper_90nm(cfg.clock_hz);
    let eff = report(&merged, &model);
    let trace = MemoryTrace::from_chip(&merged, kind.name(), net.timesteps, eval.len());

    // Degradation report: replay the eval set through the fault-free
    // oracle and compare predictions + accuracy against the faulty run.
    let mut fault_report = None;
    if !fault_plan.is_empty() {
        let mut out = RunOutput::default();
        let mut diverged = 0usize;
        let mut oracle_correct = 0usize;
        for ((st, label, _), resp) in eval.iter().zip(&responses) {
            if let Some(s) = oracle_sharded.as_mut() {
                s.run_into(st, &mut out)?;
            } else {
                oracle_mono
                    .as_mut()
                    .expect("degradation oracle built when faults are installed")
                    .run_into(st, &mut out)?;
            }
            let pred = out.predicted_class();
            if pred != resp.predicted {
                diverged += 1;
            }
            if pred == *label {
                oracle_correct += 1;
            }
        }
        fault_report = Some((oracle_correct as f64 / eval.len().max(1) as f64, diverged));
    }

    println!("\n== results ==");
    println!("accuracy:        {:.4}", merged_accuracy(&responses));
    if let Some((oracle_acc, diverged)) = fault_report {
        let (stuck, dead, flips) = merged.fault_counters();
        println!(
            "fault-free acc:  {:.4} (degradation {:+.4}, {diverged}/{} predictions diverged)",
            oracle_acc,
            merged_accuracy(&responses) - oracle_acc,
            eval.len()
        );
        println!(
            "fault activity:  {stuck} stuck-row hits, {dead} dead-slot hits, \
             {flips} events bit-flipped"
        );
    }
    if let Some(g) = golden_agree {
        println!("golden agreement: {g:.4} (simulator vs PJRT-executed JAX model)");
    }
    println!("wall time:       {wall:?} ({:.1} samples/s)", eval.len() as f64 / wall.as_secs_f64());
    println!("modeled cycles:  {} ({:.3} ms at {:.1} MHz)",
        responses.iter().map(|r| r.cycles).sum::<u64>(),
        responses.iter().map(|r| r.cycles).sum::<u64>() as f64 * cfg.clock_period() * 1e3,
        cfg.clock_hz / 1e6);
    println!("total MACs:      {}", merged.total_macs());
    println!("energy:          {:.3} µJ", eff.breakdown.total() * 1e6);
    println!("TOPS/W:          {:.2}", eff.tops_per_watt);
    println!("MEM_S&N mean:    {:.1} KB (peak {:.1} KB)", trace.mean_kb(), trace.peak_kb());

    if let Some(out) = args.get("out") {
        let j = Json::obj(vec![
            ("accuracy", merged_accuracy(&responses).into()),
            ("tops_per_watt", eff.tops_per_watt.into()),
            ("total_macs", (merged.total_macs() as usize).into()),
            ("trace", trace.to_json()),
        ]);
        std::fs::write(out, j.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Parse a `--remote-shards host:port,host:port,...` list.
fn parse_host_list(spec: &str) -> Result<Vec<String>> {
    let addrs: Vec<String> = spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if addrs.is_empty() {
        bail!("--remote-shards needs a comma-separated host:port list");
    }
    Ok(addrs)
}

/// `simulate --remote-shards` — drive already-running `shard-host`
/// processes through the distributed pipeline driver, one sample at a
/// time, optionally cross-checking every classifier train + cycle count
/// against a locally built monolithic oracle (`--check-monolithic`, the
/// `make smoke-dist` identity gate).
fn cmd_simulate_remote(args: &Args, spec: &str) -> Result<()> {
    if args.get("faults").is_some() {
        bail!(
            "--faults has no effect with --remote-shards: install the fault plan on the \
             shard-hosts — their realization is what executes"
        );
    }
    if args.get_usize("shards", 1)?.max(1) > 1 {
        bail!(
            "--shards is the in-process sharding path; with --remote-shards the hosts \
             define the topology"
        );
    }
    if args.has("golden") {
        bail!("--golden is not supported with --remote-shards");
    }
    let (mcfg, kind, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    let cfg = resolve_accel(&args.get_or("accel", "accel1"))?;
    let strategy = Strategy::parse(&args.get_or("strategy", "ilp_flow"))?;
    let analog = resolve_analog(args)?;
    let samples = args.get_usize("samples", 40)?;
    let synthetic = args.has("synthetic");
    let check_mono = args.has("check-monolithic");
    let window = args.get_usize("remote-window", 2)?.max(1);
    let net = load_network(base, &mcfg, synthetic)?;

    let addrs = parse_host_list(spec)?;
    let mut pipeline = RemoteShardPipeline::connect(
        &addrs,
        RemoteShardConfig { window, ..RemoteShardConfig::default() },
    )?;
    if pipeline.input_dim() != net.input_dim() || pipeline.output_dim() != net.output_dim() {
        bail!(
            "shard-hosts serve a {}→{} pipeline, but the local model is {}→{} — \
             start them with the same --model/--accel/--shards",
            pipeline.input_dim(),
            pipeline.output_dim(),
            net.input_dim(),
            net.output_dim()
        );
    }
    println!(
        "driving {} shard-hosts ({} → {} dims, T={}, window {window})",
        pipeline.num_shards(),
        pipeline.input_dim(),
        pipeline.output_dim(),
        pipeline.timesteps()
    );

    let eval: Vec<(SpikeTrain, usize)> = if synthetic {
        let ds = Dataset::new(kind, 3, net.timesteps);
        ds.balanced_split(samples, 0).into_iter().map(|s| (s.events, s.label)).collect()
    } else {
        load_eval(base, samples)?.into_iter().map(|(st, l, _)| (st, l)).collect()
    };
    println!("running {} samples over the wire…", eval.len());

    // The identity oracle: same (model, seed) build the hosts used, so
    // the distributed run must be bit-identical to it.
    let mut oracle = if check_mono {
        Some(Menage::build(&net, &cfg, strategy, &analog, 7)?)
    } else {
        None
    };
    let mut out = RunOutput::default();
    let mut oracle_out = RunOutput::default();
    let mut correct = 0usize;
    let mut total_cycles = 0u64;
    let t0 = Instant::now();
    for (i, (st, label)) in eval.iter().enumerate() {
        pipeline.run_into(st, &mut out)?;
        total_cycles += out.cycles;
        if out.predicted_class() == *label {
            correct += 1;
        }
        if let Some(oracle) = oracle.as_mut() {
            oracle.run_into(st, &mut oracle_out)?;
            if *out.output() != *oracle_out.output() {
                bail!(
                    "distributed-vs-monolithic mismatch: sample {i} classifier train diverges"
                );
            }
            if out.cycles != oracle_out.cycles {
                bail!(
                    "distributed-vs-monolithic mismatch: sample {i} cycles {} != {}",
                    out.cycles,
                    oracle_out.cycles
                );
            }
        }
    }
    let wall = t0.elapsed();
    if check_mono {
        println!(
            "distributed-vs-monolithic check: {} samples bit-identical (trains + cycles)",
            eval.len()
        );
    }
    let accuracy = correct as f64 / eval.len().max(1) as f64;
    let stats = pipeline.stats();
    println!("\n== results ==");
    println!("accuracy:        {accuracy:.4}");
    println!("modeled cycles:  {total_cycles}");
    println!(
        "wall time:       {wall:?} ({:.1} samples/s)",
        eval.len() as f64 / wall.as_secs_f64()
    );
    println!("boundary events per cut: {:?}", stats.boundary_events_vec());
    println!("max in-flight per link:  {:?}", stats.max_in_flight_vec());
    if let Some(outp) = args.get("out") {
        let j = Json::obj(vec![
            ("accuracy", accuracy.into()),
            ("modeled_cycles", (total_cycles as usize).into()),
            ("shards", pipeline.num_shards().into()),
            ("remote_links", stats.to_json()),
        ]);
        std::fs::write(outp, j.to_string())?;
        println!("wrote {outp}");
    }
    Ok(())
}

fn merged_accuracy(responses: &[menage::coordinator::Response]) -> f64 {
    let labelled = responses.iter().filter(|r| r.label.is_some()).count();
    if labelled == 0 {
        return f64::NAN;
    }
    responses
        .iter()
        .filter(|r| r.label == Some(r.predicted))
        .count() as f64
        / labelled as f64
}

/// Merge per-worker chips into one stats carrier (stats are additive).
/// `None` when no chip survived (every worker died before shutdown).
fn merge_chips(chips: Vec<Menage>) -> Option<Menage> {
    let mut chips = chips.into_iter();
    let mut base = chips.next()?;
    for other in chips {
        for (a, b) in base.cores.iter_mut().zip(other.cores) {
            a.stats.cycles += b.stats.cycles;
            a.stats.events_dispatched += b.stats.events_dispatched;
            a.stats.sn_rows_read += b.stats.sn_rows_read;
            a.stats.macs += b.stats.macs;
            a.stats.integrations += b.stats.integrations;
            a.stats.fire_ops += b.stats.fire_ops;
            a.stats.spikes_out += b.stats.spikes_out;
            a.stats.dropped_events += b.stats.dropped_events;
            a.stats.stuck_row_hits += b.stats.stuck_row_hits;
            a.stats.dead_slot_hits += b.stats.dead_slot_hits;
            a.stats.events_bit_flipped += b.stats.events_bit_flipped;
            a.stats
                .sn_rows_touched_per_step
                .extend(b.stats.sn_rows_touched_per_step);
            a.stats.cycles_per_step.extend(b.stats.cycles_per_step);
        }
        base.inputs_processed += other.inputs_processed;
    }
    Some(base)
}

fn cmd_waveform(args: &Args) -> Result<()> {
    args.expect_known(&["out"], &[])?;
    use menage::analog::ANeuron;
    let mut an = ANeuron::new(1, AnalogParams::paper());
    an.enable_capture();
    let mut rng = Rng::new(11);
    for _ in 0..40 {
        let packet = if rng.bernoulli(0.7) { rng.uniform(0.1, 0.5) } else { 0.0 };
        an.process(0, packet, 1.0, 0.0);
        an.lif_leak(0.9);
    }
    let wf = an.waveform();
    println!("captured {} waveform points over {:.1} ns", wf.len(), an.now * 1e9);
    println!("average power: {:.1} nW (paper: 97 nW)", an.average_power() * 1e9);
    if let Some(out) = args.get("out") {
        let j = Json::Arr(
            wf.iter()
                .map(|p| {
                    Json::obj(vec![
                        ("t", p.t.into()),
                        ("v_in", p.v_in.into()),
                        ("v_integ", p.v_integ.into()),
                        ("v_out", p.v_out.into()),
                    ])
                })
                .collect(),
        );
        std::fs::write(out, j.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `menage serve` — stand up the TCP inference server (see
/// `menage::serve` module docs for the wire protocol and threading model).
/// Runs until `--duration-secs` elapses or, with
/// `--allow-remote-shutdown`, a client sends a SHUTDOWN frame (the
/// `make smoke-serve` flow); otherwise until killed.
fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_known(
        &[
            "model",
            "accel",
            "strategy",
            "analog",
            "addr",
            "workers",
            "lanes",
            "fill-wait-us",
            "max-in-flight",
            "duration-secs",
            "shards",
            "faults",
            "chaos",
            "remote-shards",
            "remote-window",
            "session-lanes",
            "session-idle-secs",
        ],
        &["synthetic", "allow-remote-shutdown", "expand-conv"],
    )?;
    if let Some(spec) = args.get("remote-shards") {
        return cmd_serve_remote(args, &spec.to_string());
    }
    if args.get("remote-window").is_some() {
        bail!("--remote-window only applies with --remote-shards");
    }
    let (mcfg, _kind, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    let cfg = resolve_accel(&args.get_or("accel", "accel1"))?;
    let strategy = Strategy::parse(&args.get_or("strategy", "ilp_flow"))?;
    let analog = resolve_analog(args)?;
    let shards_req = args.get_usize("shards", 1)?.max(1);
    let net = maybe_expand_conv(load_network(base, &mcfg, args.has("synthetic"))?, args)?;
    let fault_plan = match args.get("faults") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::default(),
    };
    let chaos = match args.get("chaos") {
        Some(spec) => SystemChaos::parse(spec)?,
        None => SystemChaos::default(),
    };

    let serve_cfg = ServeConfig {
        workers: args.get_usize("workers", 4)?.max(1),
        lanes_per_worker: args.get_usize("lanes", 4)?.max(1),
        fill_wait: Duration::from_micros(args.get_usize("fill-wait-us", 500)? as u64),
        max_in_flight: args.get_usize("max-in-flight", 256)?.max(1),
        allow_remote_shutdown: args.has("allow-remote-shutdown"),
        chaos,
        session_lanes: args.get_usize("session-lanes", 8)?.max(1),
        session_idle: Duration::from_secs(
            args.get_usize("session-idle-secs", 60)?.max(1) as u64,
        ),
        ..ServeConfig::default()
    };
    let duration = args.get_usize("duration-secs", 0)?;
    let workers = serve_cfg.workers;
    let lanes = serve_cfg.lanes_per_worker;
    let cap = serve_cfg.max_in_flight;
    let addr = args.get_or("addr", "127.0.0.1:7471");
    if !fault_plan.is_empty() {
        println!("hardware fault injection enabled (seed {})", fault_plan.seed);
    }
    if serve_cfg.chaos.enabled() {
        println!("system chaos injection enabled — NOT a production configuration");
    }
    let (server, shard_note) = if shards_req > 1 {
        let mut sharded = ShardedMenage::build(&net, &cfg, strategy, &analog, 7, shards_req)?;
        sharded.install_faults(&fault_plan);
        // serve's --shards is a topology contract (loadgen --shards
        // asserts it over STATS): refuse to silently serve fewer shards
        // than requested instead of clamping like `simulate` does.
        if sharded.num_shards() != shards_req {
            bail!(
                "--shards {shards_req} exceeds the model's {} layers (one layer per shard max); \
                 the server would run {} shards",
                net.layers.len(),
                sharded.num_shards()
            );
        }
        let note = format!(
            ", {} shards (cut traffic {})",
            sharded.num_shards(),
            sharded.plan.cut_cost
        );
        (Server::start_sharded(&sharded, addr.as_str(), serve_cfg)?, note)
    } else {
        let mut chip = Menage::build(&net, &cfg, strategy, &analog, 7)?;
        chip.install_faults(&fault_plan);
        (Server::start(&chip, addr.as_str(), serve_cfg)?, String::new())
    };
    println!(
        "serving {} on {} — {workers} workers × {lanes} lanes, in-flight cap {cap}{shard_note}{}",
        net.name,
        server.local_addr(),
        if duration > 0 { format!(", for {duration}s") } else { String::new() }
    );

    let metrics = server.metrics();
    let started = Instant::now();
    let mut last_report = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(200));
        if server.remote_shutdown_requested() {
            println!("shutdown requested by client; draining…");
            break;
        }
        if server.quiesced() {
            eprintln!("server lost its workers; shutting down");
            break;
        }
        if duration > 0 && started.elapsed() >= Duration::from_secs(duration as u64) {
            println!("duration reached; draining…");
            break;
        }
        if last_report.elapsed() >= Duration::from_secs(10) {
            last_report = Instant::now();
            println!("stats: {}", server.stats_json());
        }
    }
    let chips = server.shutdown();
    println!("final stats: {}", metrics.to_json(started, 0, 0));
    match merge_chips(chips) {
        Some(merged) => {
            println!(
                "served {} inputs, {} synaptic events dispatched",
                merged.inputs_processed,
                merged.total_events()
            );
            if merged.has_faults() {
                let (stuck, dead, flips) = merged.fault_counters();
                println!(
                    "fault activity: {stuck} stuck-row hits, {dead} dead-slot hits, \
                     {flips} events bit-flipped"
                );
            }
        }
        None => println!("no worker chips survived shutdown; per-chip stats unavailable"),
    }
    Ok(())
}

/// `menage serve --remote-shards host:port,...` — the same TCP inference
/// front-end, but execution happens on already-running `shard-host`
/// processes: every coordinator worker clones the pipeline driver and
/// streams boundary frontiers host-to-host. The model (and any fault
/// plan) lives on the hosts; this process never builds a chip.
fn cmd_serve_remote(args: &Args, spec: &str) -> Result<()> {
    for k in ["model", "accel", "strategy", "analog", "shards", "faults"] {
        if args.get(k).is_some() {
            bail!(
                "--{k} has no effect with --remote-shards: the model (and any fault plan) \
                 lives on the shard-hosts"
            );
        }
    }
    if args.has("synthetic") {
        bail!("--synthetic has no effect with --remote-shards: the shard-hosts own the model");
    }
    let chaos = match args.get("chaos") {
        Some(spec) => SystemChaos::parse(spec)?,
        None => SystemChaos::default(),
    };
    let serve_cfg = ServeConfig {
        workers: args.get_usize("workers", 4)?.max(1),
        lanes_per_worker: args.get_usize("lanes", 4)?.max(1),
        fill_wait: Duration::from_micros(args.get_usize("fill-wait-us", 500)? as u64),
        max_in_flight: args.get_usize("max-in-flight", 256)?.max(1),
        allow_remote_shutdown: args.has("allow-remote-shutdown"),
        chaos,
        ..ServeConfig::default()
    };
    let duration = args.get_usize("duration-secs", 0)?;
    let workers = serve_cfg.workers;
    let lanes = serve_cfg.lanes_per_worker;
    let cap = serve_cfg.max_in_flight;
    let addr = args.get_or("addr", "127.0.0.1:7471");
    if serve_cfg.chaos.enabled() {
        println!("system chaos injection enabled — NOT a production configuration");
    }
    let addrs = parse_host_list(spec)?;
    let window = args.get_usize("remote-window", 2)?.max(1);
    let pipeline = RemoteShardPipeline::connect(
        &addrs,
        RemoteShardConfig { window, ..RemoteShardConfig::default() },
    )?;
    let server = Server::start_remote(&pipeline, addr.as_str(), serve_cfg)?;
    println!(
        "serving a {}-shard remote pipeline ({} → {} dims, T={}, window {window}) on {} — \
         {workers} workers × {lanes} lanes, in-flight cap {cap}{}",
        pipeline.num_shards(),
        pipeline.input_dim(),
        pipeline.output_dim(),
        pipeline.timesteps(),
        server.local_addr(),
        if duration > 0 { format!(", for {duration}s") } else { String::new() }
    );

    let metrics = server.metrics();
    let started = Instant::now();
    let mut last_report = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(200));
        if server.remote_shutdown_requested() {
            println!("shutdown requested by client; draining…");
            break;
        }
        if server.quiesced() {
            eprintln!("server lost its workers; shutting down");
            break;
        }
        if duration > 0 && started.elapsed() >= Duration::from_secs(duration as u64) {
            println!("duration reached; draining…");
            break;
        }
        if last_report.elapsed() >= Duration::from_secs(10) {
            last_report = Instant::now();
            println!("stats: {}", server.stats_json());
        }
    }
    let stats = pipeline.stats();
    let chips = server.shutdown();
    debug_assert!(chips.is_empty(), "remote workers own no local chips");
    println!("final stats: {}", metrics.to_json(started, 0, 0));
    println!("boundary events per cut: {:?}", stats.boundary_events_vec());
    println!("max in-flight per link:  {:?}", stats.max_in_flight_vec());
    println!("per-core stats live on the shard-hosts — query their STATS frames");
    Ok(())
}

/// `menage shard-host` — host ONE chip of the shard plan over TCP (see
/// `menage::serve::shard_host`). Builds the **full** `ShardedMenage`
/// (same seed 7 every `serve`/`simulate` build uses, same fault plan
/// realization) and serves the `--shard-index`-th slice; the other
/// slices are dropped. Runs until `--duration-secs` elapses or, with
/// `--allow-remote-shutdown`, a client sends SHUTDOWN.
fn cmd_shard_host(args: &Args) -> Result<()> {
    args.expect_known(
        &[
            "model",
            "accel",
            "strategy",
            "analog",
            "addr",
            "shards",
            "shard-index",
            "faults",
            "duration-secs",
        ],
        &["synthetic", "allow-remote-shutdown", "expand-conv"],
    )?;
    let (mcfg, _kind, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    let cfg = resolve_accel(&args.get_or("accel", "accel1"))?;
    let strategy = Strategy::parse(&args.get_or("strategy", "ilp_flow"))?;
    let analog = resolve_analog(args)?;
    let shards_req = args.get_usize("shards", 2)?.max(1);
    let index: usize = args
        .get("shard-index")
        .ok_or_else(|| anyhow!("--shard-index is required (which shard of the plan this host serves)"))?
        .parse()
        .context("--shard-index")?;
    let net = maybe_expand_conv(load_network(base, &mcfg, args.has("synthetic"))?, args)?;
    let fault_plan = match args.get("faults") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::default(),
    };
    let mut sharded = ShardedMenage::build(&net, &cfg, strategy, &analog, 7, shards_req)?;
    // Same topology contract as `serve --shards`: the driver validates
    // shard count and dims over STATS, so refuse to silently serve a
    // different plan than requested.
    if sharded.num_shards() != shards_req {
        bail!(
            "--shards {shards_req} exceeds the model's {} layers (one layer per shard max); \
             this host would serve a {}-shard plan",
            net.layers.len(),
            sharded.num_shards()
        );
    }
    sharded.install_faults(&fault_plan);
    if !fault_plan.is_empty() {
        println!("hardware fault injection enabled (seed {})", fault_plan.seed);
    }
    let host_cfg = ShardHostConfig {
        allow_remote_shutdown: args.has("allow-remote-shutdown"),
        ..ShardHostConfig::default()
    };
    let addr = args.get_or("addr", "127.0.0.1:7475");
    let duration = args.get_usize("duration-secs", 0)?;
    let range = sharded.plan.ranges()[index.min(sharded.num_shards() - 1)].clone();
    let server = ShardHostServer::start(&sharded, index, addr.as_str(), host_cfg)?;
    println!(
        "shard-host {index}/{shards_req}: serving layers {}..{} of {} on {}{}",
        range.start,
        range.end,
        net.name,
        server.local_addr(),
        if duration > 0 { format!(", for {duration}s") } else { String::new() }
    );
    let started = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(200));
        if server.remote_shutdown_requested() {
            println!("shutdown requested by client; stopping…");
            break;
        }
        if duration > 0 && started.elapsed() >= Duration::from_secs(duration as u64) {
            println!("duration reached; stopping…");
            break;
        }
    }
    println!("final stats: {}", server.stats_json());
    server.shutdown();
    Ok(())
}

/// Per-connection load-generator tallies, merged for the final report.
///
/// Failures split into **transient** (a retry or reconnect ultimately got
/// an answer — `reconnects`/`retried`/`recovered`) and **terminal**
/// (`mismatched`/`unanswered`/`lost`); only terminal losses fail the
/// integrity gate.
#[derive(Default)]
struct LoadStats {
    lat_us: Vec<f64>,
    ok: usize,
    overload: usize,
    deadline: usize,
    errors: usize,
    mismatched: usize,
    unanswered: usize,
    events_sent: u64,
    /// Connections re-established after a socket error mid-run.
    reconnects: usize,
    /// Requests re-sent (lost response or connection loss).
    retried: usize,
    /// Requests answered after at least one retry.
    recovered: usize,
    /// Requests abandoned after exhausting the retry budget (terminal).
    lost: usize,
}

/// What one load-generator connection is asked to do.
struct LoadPlan {
    addr: String,
    conn_idx: usize,
    requests: usize,
    pipeline: usize,
    input_dim: usize,
    timesteps: usize,
    classes: usize,
    rate: f64,
    deadline_ms: u32,
    seed: u64,
}

/// One in-flight load-generator request: enough to resend it verbatim
/// after a lost response or a torn connection.
struct PendingReq {
    train: SpikeTrain,
    sent: Instant,
    attempts: usize,
}

/// Retry budget per request: after this many sends a request is counted
/// as a terminal loss instead of retried again.
const LOADGEN_MAX_ATTEMPTS: usize = 4;
/// Receive window per poll; several expire before a request is declared
/// stale.
const LOADGEN_RECV_WINDOW: Duration = Duration::from_millis(500);
/// A request unanswered this long is presumed dropped and re-sent.
const LOADGEN_RETRY_AFTER: Duration = Duration::from_secs(2);

/// Re-establish a torn connection and resend everything outstanding under
/// fresh ids (each connection's id space restarts at 0, so old ids are
/// remapped here). Requests out of retry budget become terminal `lost`.
fn loadgen_reconnect(
    plan: &LoadPlan,
    stats: &mut LoadStats,
    outstanding: &mut BTreeMap<u64, PendingReq>,
    done: &mut usize,
) -> Result<Client> {
    stats.reconnects += 1;
    let mut carry: Vec<PendingReq> = std::mem::take(outstanding).into_values().collect();
    carry.retain(|p| {
        if p.attempts >= LOADGEN_MAX_ATTEMPTS {
            stats.lost += 1;
            *done += 1;
            false
        } else {
            true
        }
    });
    stats.retried += carry.len();
    for p in carry.iter_mut() {
        p.attempts += 1;
    }
    let mut last_err = None;
    for attempt in 0..8u64 {
        let mut client = match Client::connect_backoff(
            plan.addr.as_str(),
            40,
            Duration::from_millis(50),
            Duration::from_millis(500),
            plan.seed
                .wrapping_mul(31)
                .wrapping_add(plan.conn_idx as u64)
                .wrapping_add(stats.reconnects as u64)
                .wrapping_add(attempt << 32),
        ) {
            Ok(c) => c,
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        };
        let mut ids = Vec::with_capacity(carry.len());
        let mut torn = false;
        for p in carry.iter_mut() {
            p.sent = Instant::now();
            match client.send_infer(&p.train, plan.deadline_ms, None) {
                Ok(id) => ids.push(id),
                Err(e) => {
                    last_err = Some(e);
                    torn = true;
                    break;
                }
            }
        }
        if !torn {
            for (id, p) in ids.into_iter().zip(carry.drain(..)) {
                outstanding.insert(id, p);
            }
            return Ok(client);
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow!("loadgen reconnect failed")))
        .context("re-establishing loadgen connection")
}

/// One load-generator connection: keep up to `pipeline` requests
/// outstanding until `requests` are answered, with heterogeneous train
/// lengths (cycling 1..=timesteps) at the given spike rate.
///
/// Survives chaos: a torn connection is re-established and outstanding
/// requests resent under fresh ids; a response unanswered past
/// [`LOADGEN_RETRY_AFTER`] is presumed dropped and resent on the live
/// connection (the abandoned id goes to a retired set so a slow duplicate
/// does not count as a mismatch). A request is terminal only after
/// [`LOADGEN_MAX_ATTEMPTS`] sends.
fn loadgen_connection(plan: &LoadPlan) -> Result<LoadStats> {
    // Jittered exponential backoff with a per-connection seed, so N
    // connections racing one server start don't retry in lockstep.
    let mut client = Client::connect_backoff(
        plan.addr.as_str(),
        40,
        Duration::from_millis(50),
        Duration::from_millis(500),
        plan.seed.wrapping_mul(31).wrapping_add(plan.conn_idx as u64),
    )?;
    let mut rng = Rng::new(plan.seed.wrapping_mul(10_007).wrapping_add(plan.conn_idx as u64));
    let mut stats = LoadStats::default();
    let mut outstanding: BTreeMap<u64, PendingReq> = BTreeMap::new();
    // Ids abandoned by a same-connection retry: replies may still arrive
    // for them and must not count as mismatches. Cleared on reconnect
    // (the old connection's replies can no longer arrive).
    let mut retired: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
    let (mut sent, mut done) = (0usize, 0usize);
    while done < plan.requests {
        while sent < plan.requests && outstanding.len() < plan.pipeline {
            let t = 1 + (sent * 7 + plan.conn_idx) % plan.timesteps.max(1);
            let train = SpikeTrain::bernoulli(plan.input_dim, t, plan.rate, &mut rng);
            stats.events_sent += train.total_spikes() as u64;
            match client.send_infer(&train, plan.deadline_ms, None) {
                Ok(id) => {
                    outstanding
                        .insert(id, PendingReq { train, sent: Instant::now(), attempts: 1 });
                    sent += 1;
                }
                Err(_) => {
                    retired.clear();
                    client = loadgen_reconnect(plan, &mut stats, &mut outstanding, &mut done)?;
                    // The fresh train was never registered; re-draw it on
                    // the next pass.
                    stats.events_sent -= train.total_spikes() as u64;
                }
            }
        }
        if done >= plan.requests {
            break;
        }
        match client.recv_reply_timeout(LOADGEN_RECV_WINDOW) {
            Ok(Some(Reply::Infer(r))) => match outstanding.remove(&r.id) {
                Some(p) => {
                    done += 1;
                    stats.lat_us.push(p.sent.elapsed().as_secs_f64() * 1e6);
                    if p.attempts > 1 {
                        stats.recovered += 1;
                    }
                    // Sanity only; bit-exactness is pinned by
                    // tests/serve_roundtrip.rs.
                    if (r.predicted as usize) < plan.classes
                        && r.output.num_neurons == plan.classes
                    {
                        stats.ok += 1;
                    } else {
                        stats.mismatched += 1;
                    }
                }
                None => {
                    if !retired.remove(&r.id) {
                        stats.mismatched += 1;
                        done += 1;
                    }
                }
            },
            Ok(Some(Reply::Error(e))) => {
                if e.id != NO_ID && retired.remove(&e.id) {
                    // Stale error for an attempt already abandoned.
                } else if e.id != NO_ID {
                    match outstanding.remove(&e.id) {
                        Some(p) => {
                            done += 1;
                            if p.attempts > 1 {
                                stats.recovered += 1;
                            }
                            match e.code {
                                ErrorCode::Overload => stats.overload += 1,
                                ErrorCode::DeadlineExceeded => stats.deadline += 1,
                                _ => stats.errors += 1,
                            }
                        }
                        None => {
                            stats.mismatched += 1;
                            done += 1;
                        }
                    }
                } else {
                    bail!(
                        "connection-level server error: [{}] {}",
                        e.code.name(),
                        e.message
                    );
                }
            }
            Ok(Some(_)) => {}
            Ok(None) => {
                // Receive window expired: resend requests old enough that
                // their response is presumed dropped.
                let now = Instant::now();
                let stale: Vec<u64> = outstanding
                    .iter()
                    .filter(|(_, p)| now.duration_since(p.sent) >= LOADGEN_RETRY_AFTER)
                    .map(|(&id, _)| id)
                    .collect();
                let mut torn = false;
                for id in stale {
                    let mut p = outstanding.remove(&id).expect("stale id present");
                    if p.attempts >= LOADGEN_MAX_ATTEMPTS {
                        stats.lost += 1;
                        done += 1;
                        continue;
                    }
                    p.attempts += 1;
                    p.sent = Instant::now();
                    stats.retried += 1;
                    match client.send_infer(&p.train, plan.deadline_ms, None) {
                        Ok(nid) => {
                            retired.insert(id);
                            outstanding.insert(nid, p);
                        }
                        Err(_) => {
                            // Connection died under the resend: put the
                            // request back and fall through to reconnect.
                            outstanding.insert(id, p);
                            torn = true;
                            break;
                        }
                    }
                }
                if torn {
                    retired.clear();
                    client = loadgen_reconnect(plan, &mut stats, &mut outstanding, &mut done)?;
                }
            }
            Err(_) => {
                retired.clear();
                client = loadgen_reconnect(plan, &mut stats, &mut outstanding, &mut done)?;
            }
        }
    }
    stats.unanswered = outstanding.len();
    Ok(stats)
}

/// What one streaming load-generator connection is asked to do
/// (`loadgen --stream`): open `sessions` sessions one after another and
/// stream each train through in `chunk_timesteps`-step SESSION_CHUNK
/// frames.
struct StreamPlan {
    addr: String,
    conn_idx: usize,
    sessions: usize,
    chunk_timesteps: usize,
    input_dim: usize,
    timesteps: usize,
    classes: usize,
    rate: f64,
    seed: u64,
}

/// One streaming load-generator connection.
///
/// Each chunk is a synchronous round trip (per-chunk latency is the
/// metric of interest), and the server's running prediction is checked
/// against a client-side fold of the chunk outputs — the server computes
/// it from session-cumulative class counts, so any divergence means lane
/// state leaked or was dropped between chunks.
///
/// Sessions are stateful: the one-shot path's retry machinery cannot
/// replay a half-streamed train through a fresh session, so a failed
/// chunk round trip abandons the session as a terminal `lost` instead of
/// reconnecting.
fn loadgen_stream_connection(plan: &StreamPlan) -> Result<LoadStats> {
    let mut client = Client::connect_backoff(
        plan.addr.as_str(),
        40,
        Duration::from_millis(50),
        Duration::from_millis(500),
        plan.seed.wrapping_mul(31).wrapping_add(plan.conn_idx as u64),
    )?;
    let mut rng = Rng::new(plan.seed.wrapping_mul(10_007).wrapping_add(plan.conn_idx as u64));
    let mut stats = LoadStats::default();
    for s in 0..plan.sessions {
        let sid = ((plan.conn_idx as u64) << 32) | s as u64;
        if let Err(e) = client.open_session(sid) {
            // Admission rejects (session table full) are an expected
            // outcome under load, not an integrity failure.
            if format!("{e:#}").contains("[overload]") {
                stats.overload += 1;
            } else {
                stats.errors += 1;
            }
            continue;
        }
        // Heterogeneous train lengths, same scheme as the one-shot path.
        let steps = 1 + (s * 7 + plan.conn_idx) % plan.timesteps.max(1);
        let train = SpikeTrain::bernoulli(plan.input_dim, steps, plan.rate, &mut rng);
        let mut class_counts = vec![0u64; plan.classes];
        let (mut t0, mut seq, mut bad) = (0usize, 0u64, false);
        while t0 < steps {
            let t1 = (t0 + plan.chunk_timesteps).min(steps);
            let chunk = train.slice_steps(t0..t1);
            stats.events_sent += chunk.total_spikes() as u64;
            let sent = Instant::now();
            match client.session_chunk(sid, seq, &chunk) {
                Ok(out) => {
                    stats.lat_us.push(sent.elapsed().as_secs_f64() * 1e6);
                    for (i, &c) in out.output.counts().iter().enumerate() {
                        class_counts[i] += c as u64;
                    }
                    // Same strict-`>` argmax as `SpikeTrain::argmax_class`
                    // (ties toward the lower class index).
                    let mut expect = 0usize;
                    for (i, &v) in class_counts.iter().enumerate() {
                        if v > class_counts[expect] {
                            expect = i;
                        }
                    }
                    if out.predicted as usize == expect
                        && out.output.num_neurons == plan.classes
                    {
                        stats.ok += 1;
                    } else {
                        stats.mismatched += 1;
                        bad = true;
                    }
                }
                Err(_) => {
                    stats.lost += 1;
                    bad = true;
                }
            }
            if bad {
                break;
            }
            seq += 1;
            t0 = t1;
        }
        if bad {
            continue;
        }
        // The close-ack confirms the lane's stats were folded back into
        // the chip totals; losing it would leak the lane until the idle
        // sweep, so it counts against integrity.
        if client.close_session(sid).is_err() {
            stats.lost += 1;
        }
    }
    Ok(stats)
}

/// `menage loadgen` — drive a running `menage serve` over N concurrent
/// connections and report throughput + latency percentiles, emitting the
/// machine-readable `BENCH_serve.json` for the cross-PR perf trajectory.
fn cmd_loadgen(args: &Args) -> Result<()> {
    args.expect_known(
        &[
            "addr",
            "connections",
            "requests",
            "pipeline",
            "rate",
            "deadline-ms",
            "seed",
            "shards",
            "out",
            "chunk-timesteps",
        ],
        &["shutdown-server", "profile", "stream"],
    )?;
    let addr = args.get_or("addr", "127.0.0.1:7471");
    let connections = args.get_usize("connections", 8)?.max(1);
    let total: usize = args.get_usize("requests", 256)?;
    let pipeline = args.get_usize("pipeline", 4)?.max(1);
    let rate: f64 = match args.get("rate") {
        None => 0.1,
        Some(v) => v.parse().with_context(|| format!("--rate {v:?}"))?,
    };
    let deadline_ms = args.get_usize("deadline-ms", 0)? as u32;
    let seed = args.get_usize("seed", 1)? as u64;
    let out = args.get_or("out", "BENCH_serve.json");
    let profile_flag = args.has("profile");
    let stream = args.has("stream");
    if !stream && args.get("chunk-timesteps").is_some() {
        bail!("--chunk-timesteps only applies with --stream");
    }
    let chunk_timesteps = args.get_usize("chunk-timesteps", 4)?.max(1);

    // Probe: wait for the server and learn the model's dimensions.
    // `--profile` requires the versioned snapshot (it diffs the profile
    // block pre→post), so schema drift fails here, before any load runs.
    let mut probe = Client::connect_backoff(
        addr.as_str(),
        40,
        Duration::from_millis(50),
        Duration::from_millis(500),
        seed,
    )?;
    let pre = if profile_flag { probe.stats_versioned()? } else { probe.stats()? };
    let model = pre.get("model")?;
    let input_dim = model.get("input_dim")?.as_usize()?;
    let timesteps = model.get("timesteps")?.as_usize()?;
    let classes = model.get("classes")?.as_usize()?;
    // Shard topology check: a monolithic server reports no `shards` block
    // (counted as 1); `--shards N` asserts the server actually runs N.
    let server_shards = match pre.get("shards") {
        Ok(Json::Arr(a)) => a.len(),
        _ => 1,
    };
    let expect_shards = args.get_usize("shards", 0)?;
    if expect_shards > 0 && server_shards != expect_shards {
        bail!("server runs {server_shards} shard(s), --shards expected {expect_shards}");
    }
    if stream {
        println!(
            "loadgen --stream → {addr}: {connections} connections, {total} sessions in \
             {chunk_timesteps}-step chunks (input_dim {input_dim}, T≤{timesteps}, rate {rate}, \
             {server_shards} shard(s))"
        );
    } else {
        println!(
            "loadgen → {addr}: {connections} connections × pipeline {pipeline}, {total} requests \
             (input_dim {input_dim}, T≤{timesteps}, rate {rate}, {server_shards} shard(s))"
        );
    }

    let t0 = Instant::now();
    let handles: Vec<std::thread::JoinHandle<Result<LoadStats>>> = (0..connections)
        .map(|c| {
            let share = total / connections + usize::from(c < total % connections);
            if stream {
                let plan = StreamPlan {
                    addr: addr.clone(),
                    conn_idx: c,
                    sessions: share,
                    chunk_timesteps,
                    input_dim,
                    timesteps,
                    classes,
                    rate,
                    seed,
                };
                std::thread::spawn(move || loadgen_stream_connection(&plan))
            } else {
                let plan = LoadPlan {
                    addr: addr.clone(),
                    conn_idx: c,
                    requests: share,
                    pipeline,
                    input_dim,
                    timesteps,
                    classes,
                    rate,
                    deadline_ms,
                    seed,
                };
                std::thread::spawn(move || loadgen_connection(&plan))
            }
        })
        .collect();
    let mut agg = LoadStats::default();
    for h in handles {
        let s = h.join().expect("loadgen connection thread panicked")?;
        agg.lat_us.extend(&s.lat_us);
        agg.ok += s.ok;
        agg.overload += s.overload;
        agg.deadline += s.deadline;
        agg.errors += s.errors;
        agg.mismatched += s.mismatched;
        agg.unanswered += s.unanswered;
        agg.events_sent += s.events_sent;
        agg.reconnects += s.reconnects;
        agg.retried += s.retried;
        agg.recovered += s.recovered;
        agg.lost += s.lost;
    }
    let wall = t0.elapsed();

    let mut q = Quantiles::new();
    for &l in &agg.lat_us {
        q.add(l);
    }
    let answered = agg.ok + agg.overload + agg.deadline + agg.errors + agg.mismatched;
    let rps = answered as f64 / wall.as_secs_f64().max(1e-9);
    let eps = agg.events_sent as f64 / wall.as_secs_f64().max(1e-9);
    let mean_us = if agg.lat_us.is_empty() {
        f64::NAN
    } else {
        agg.lat_us.iter().sum::<f64>() / agg.lat_us.len() as f64
    };

    let mut table = Table::new(
        if stream {
            // In stream mode the latency sample set and the ok/mismatched
            // counters are per *chunk*; overload/errors/lost per session.
            format!("loadgen --stream: {total} sessions over {connections} connections")
        } else {
            format!("loadgen: {total} requests over {connections} connections")
        },
        &["metric", "value"],
    );
    let mut row = |k: &str, v: String| table.row(&[k.to_string(), v]);
    if stream {
        row("chunks answered", answered.to_string());
    } else {
        row("answered", format!("{answered} / {total}"));
    }
    row("ok", agg.ok.to_string());
    row("overload-rejected", agg.overload.to_string());
    row("deadline-expired", agg.deadline.to_string());
    row("other errors", agg.errors.to_string());
    row("mismatched", agg.mismatched.to_string());
    row("unanswered", agg.unanswered.to_string());
    row("reconnects", agg.reconnects.to_string());
    row("retried", agg.retried.to_string());
    row("recovered", agg.recovered.to_string());
    row("lost (terminal)", agg.lost.to_string());
    row("wall time", format!("{:.3}s", wall.as_secs_f64()));
    row("throughput", format!("{rps:.1} {}", if stream { "chunks/s" } else { "req/s" }));
    row("event throughput", format!("{:.2} M events/s", eps / 1e6));
    row("latency mean", format!("{mean_us:.0} µs"));
    row("latency p50", format!("{:.0} µs", q.quantile(0.50)));
    row("latency p90", format!("{:.0} µs", q.quantile(0.90)));
    row("latency p99", format!("{:.0} µs", q.quantile(0.99)));
    row("latency max", format!("{:.0} µs", q.quantile(1.0)));
    table.print();

    // Server-side view after the run (queue depths, micro-batch effects).
    // The probe's idle connection may have been severed by chaos injection
    // (`serve --chaos reset=N`) during the run — reconnect once rather
    // than failing a run whose data connections all recovered.
    let fetch_post = |c: &mut Client| if profile_flag { c.stats_versioned() } else { c.stats() };
    let post = match fetch_post(&mut probe) {
        Ok(j) => j,
        Err(_) => {
            probe =
                Client::connect_retry(addr.as_str(), 20, Duration::from_millis(50))?;
            fetch_post(&mut probe)?
        }
    };
    // Server-side stage histograms (client-vs-server latency attribution:
    // the client percentiles below include the wire and client queuing,
    // these partition the server-internal path). Null against a pre-profile
    // server rather than failing a plain run.
    let server_stages = post
        .opt("profile")
        .and_then(|p| p.opt("stages"))
        .cloned()
        .unwrap_or(Json::Null);
    let profile_delta = if profile_flag {
        loadgen_profile_delta(&pre, &post)?
    } else {
        Json::Null
    };
    let j = Json::obj(vec![
        ("bench", "serve".into()),
        ("mode", if stream { "stream" } else { "oneshot" }.into()),
        ("addr", addr.as_str().into()),
        ("connections", connections.into()),
        ("requests", total.into()),
        ("chunk_timesteps", if stream { chunk_timesteps.into() } else { Json::Null }),
        ("pipeline", pipeline.into()),
        ("rate", rate.into()),
        ("deadline_ms", (deadline_ms as usize).into()),
        ("server_shards", server_shards.into()),
        ("ok", agg.ok.into()),
        ("overload_rejected", agg.overload.into()),
        ("deadline_expired", agg.deadline.into()),
        ("errors", agg.errors.into()),
        ("mismatched", agg.mismatched.into()),
        ("unanswered", agg.unanswered.into()),
        ("reconnects", agg.reconnects.into()),
        ("retried", agg.retried.into()),
        ("recovered", agg.recovered.into()),
        ("lost", agg.lost.into()),
        ("wall_s", wall.as_secs_f64().into()),
        ("requests_per_s", rps.into()),
        ("events_per_s", eps.into()),
        (
            "latency_us",
            // NaN (empty sample set) must not leak into the JSON output.
            Json::obj(
                [
                    ("mean", mean_us),
                    ("p50", q.quantile(0.50)),
                    ("p90", q.quantile(0.90)),
                    ("p99", q.quantile(0.99)),
                    ("max", q.quantile(1.0)),
                ]
                .into_iter()
                .map(|(k, v)| (k, if v.is_nan() { Json::Null } else { Json::Num(v) }))
                .collect(),
            ),
        ),
        ("server_stages", server_stages),
        ("profile_delta", profile_delta),
        ("server", post),
    ]);
    emit_json_file(out.as_str(), &j);

    if args.has("shutdown-server") {
        // Same chaos tolerance as the post-run stats: one reconnect before
        // giving up on the shutdown handshake.
        if probe.request_shutdown().is_err() {
            probe =
                Client::connect_retry(addr.as_str(), 20, Duration::from_millis(50))?;
            probe.request_shutdown()?;
        }
        println!("server shutdown requested");
    }
    // Integrity gate: only *terminal* losses fail the run. Transient
    // failures that were retried and recovered (reconnects, resends) are
    // reported above but are exactly what the self-healing path is for.
    if agg.mismatched > 0 || agg.unanswered > 0 || agg.lost > 0 {
        bail!(
            "loadgen integrity failure: {} mismatched, {} unanswered, {} lost after retries",
            agg.mismatched,
            agg.unanswered,
            agg.lost
        );
    }
    Ok(())
}

/// Counter fields of a `profile` cores/shards row, render order (the
/// [`menage::obs::CoreSample`] JSON field names).
const PROFILE_COUNTERS: [&str; 7] =
    ["cycles", "events", "sn_rows", "macs", "integrations", "fire_ops", "spikes"];

/// `loadgen --profile`: the run's execution-profile delta (post − pre
/// STATS probes), per core and per shard — what this run itself cost the
/// engine, independent of any earlier traffic on the same server.
fn loadgen_profile_delta(pre: &Json, post: &Json) -> Result<Json> {
    let delta_rows = |pre_rows: &[Json], post_rows: &[Json], id_field: &str| -> Result<Json> {
        let mut out = Vec::new();
        for row in post_rows {
            let id = row.get(id_field)?.as_usize()?;
            let base = pre_rows
                .iter()
                .find(|p| p.get(id_field).ok().and_then(|v| v.as_usize().ok()) == Some(id));
            let mut fields = vec![(id_field, id.into())];
            for c in PROFILE_COUNTERS {
                let cur = row.get(c)?.as_f64()?;
                let before = base
                    .and_then(|p| p.get(c).ok())
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0);
                fields.push((c, ((cur - before).max(0.0) as usize).into()));
            }
            out.push(Json::obj(fields));
        }
        Ok(Json::Arr(out))
    };
    let pre_p = pre.get("profile").context("pre-run STATS carries no `profile` block")?;
    let post_p = post.get("profile").context("post-run STATS carries no `profile` block")?;
    Ok(Json::obj(vec![
        (
            "cores",
            delta_rows(pre_p.get("cores")?.as_arr()?, post_p.get("cores")?.as_arr()?, "core")?,
        ),
        (
            "shards",
            delta_rows(pre_p.get("shards")?.as_arr()?, post_p.get("shards")?.as_arr()?, "shard")?,
        ),
    ]))
}

/// Render one summary cell for `menage top`: numbers rounded to integers,
/// anything else (null percentiles of an empty histogram) as "-".
fn top_cell(v: Option<&Json>) -> String {
    match v {
        Some(Json::Num(x)) => format!("{x:.0}"),
        _ => "-".to_string(),
    }
}

/// Render a `profile` cores/shards counter array as a table. With a
/// previous snapshot (`prev` rows + window length in seconds) the cells
/// are windowed per-second *rates*; otherwise cumulative totals.
fn top_counter_table(
    title: String,
    id_field: &str,
    rows: &[Json],
    prev: Option<(&[Json], f64)>,
) -> Result<()> {
    let unit = if prev.is_some() { "/s" } else { "" };
    let mut headers: Vec<String> = vec![id_field.to_string()];
    headers.extend(PROFILE_COUNTERS.iter().map(|c| format!("{c}{unit}")));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hdr);
    for row in rows {
        let id = row.get(id_field)?.as_usize()?;
        let mut cells = vec![id.to_string()];
        for c in PROFILE_COUNTERS {
            let cur = row.get(c)?.as_f64()?;
            cells.push(match prev {
                Some((prev_rows, secs)) => {
                    let base = prev_rows
                        .iter()
                        .find(|p| {
                            p.get(id_field).ok().and_then(|v| v.as_usize().ok()) == Some(id)
                        })
                        .and_then(|p| p.get(c).ok())
                        .and_then(|v| v.as_f64().ok())
                        .unwrap_or(0.0);
                    format!("{:.0}", (cur - base).max(0.0) / secs.max(1e-9))
                }
                None => format!("{cur:.0}"),
            });
        }
        t.row(&cells);
    }
    t.print();
    Ok(())
}

/// Render one `menage top` frame from a versioned STATS snapshot.
/// `window` carries the previous snapshot and its age in seconds; when
/// present the execution counters become windowed per-second rates.
fn render_top(snap: &Json, window: Option<(&Json, f64)>) -> Result<()> {
    // The profile block is the point of the command: its absence is a hard
    // error (`make smoke-obs` uses `top --once` as exactly this assertion).
    let profile = snap
        .get("profile")
        .context("STATS snapshot carries no `profile` block")?;
    if matches!(profile, Json::Null) {
        bail!("STATS `profile` block is null");
    }

    // Header: uptime / load / end-to-end latency, dash for absent fields.
    let num = |path: &[&str]| -> String {
        let mut v = snap;
        for k in path {
            match v.opt(k) {
                Some(n) => v = n,
                None => return "-".to_string(),
            }
        }
        match v {
            Json::Num(x) => format!("{x:.0}"),
            _ => "-".to_string(),
        }
    };
    println!(
        "uptime {}s  queue {}  in-flight {}  req/s {}  latency p50/p99/max {}/{}/{} µs",
        num(&["uptime_s"]),
        num(&["queue_depth"]),
        num(&["in_flight"]),
        num(&["throughput", "requests_per_s"]),
        num(&["latency_us", "p50"]),
        num(&["latency_us", "p99"]),
        num(&["latency_us", "max"]),
    );

    // Per-stage trace-span histograms, pipeline order.
    let stages = profile.get("stages")?;
    let mut t = Table::new(
        "request stages (server-side, µs)",
        &["stage", "count", "mean", "p50", "p90", "p99", "max"],
    );
    for name in ["admit", "queue", "dispatch", "step", "egress"] {
        let s = stages.get(name)?;
        t.row(&[
            name.to_string(),
            top_cell(s.opt("count")),
            top_cell(s.opt("mean")),
            top_cell(s.opt("p50")),
            top_cell(s.opt("p90")),
            top_cell(s.opt("p99")),
            top_cell(s.opt("max")),
        ]);
    }
    t.print();

    // Execution profile: shards first (the placement-relevant view), then
    // the per-core breakdown.
    let prev_profile = window.and_then(|(p, secs)| p.opt("profile").map(|pp| (pp, secs)));
    let mode = |secs: Option<f64>| match secs {
        Some(s) => format!("windowed, {s:.1}s"),
        None => "cumulative".to_string(),
    };
    let shards = profile.get("shards")?.as_arr()?;
    if !shards.is_empty() {
        let prev = prev_profile.and_then(|(pp, secs)| {
            pp.opt("shards").and_then(|v| v.as_arr().ok()).map(|a| (a, secs))
        });
        top_counter_table(
            format!("per-shard execution ({})", mode(prev.map(|(_, s)| s))),
            "shard",
            shards,
            prev,
        )?;
    }
    let cores = profile.get("cores")?.as_arr()?;
    if cores.is_empty() {
        println!("(no local cores — execution counters live in the shard hosts' own STATS)");
    } else {
        let prev = prev_profile.and_then(|(pp, secs)| {
            pp.opt("cores").and_then(|v| v.as_arr().ok()).map(|a| (a, secs))
        });
        top_counter_table(
            format!("per-core execution ({})", mode(prev.map(|(_, s)| s))),
            "core",
            cores,
            prev,
        )?;
    }

    // Distributed pipelines: per-link wire/wait attribution.
    if let Some(links) = snap.opt("remote_links") {
        let cols = [
            "boundary_events",
            "steps_sent",
            "acks",
            "in_flight",
            "max_in_flight",
            "step_cycles",
            "wire_us",
            "wait_us",
        ];
        let mut hdr = vec!["link"];
        hdr.extend(cols);
        let mut t = Table::new("remote links", &hdr);
        let n = links.get("steps_sent")?.as_arr()?.len();
        for k in 0..n {
            let mut cells = vec![k.to_string()];
            for col in cols {
                let v = links.opt(col).and_then(|a| a.as_arr().ok()).and_then(|a| a.get(k));
                cells.push(top_cell(v));
            }
            t.row(&cells);
        }
        t.print();
    }

    // Tail forensics: which stage of the slowest requests dominated.
    let slowest = profile.get("slowest")?.as_arr()?;
    if !slowest.is_empty() {
        let mut t = Table::new(
            "slowest traces (µs)",
            &["id", "total", "queue", "dispatch", "step", "egress"],
        );
        for r in slowest {
            t.row(&[
                top_cell(r.opt("id")),
                top_cell(r.opt("total_us")),
                top_cell(r.opt("queue_us")),
                top_cell(r.opt("dispatch_us")),
                top_cell(r.opt("step_us")),
                top_cell(r.opt("egress_us")),
            ]);
        }
        t.print();
    }
    Ok(())
}

/// `menage top` — live profiling dashboard: poll a running server's
/// versioned STATS snapshot and render the observability plane (per-stage
/// trace spans, per-core/per-shard execution counters, remote-link gauges,
/// slowest traces). From the second poll on, execution counters render as
/// windowed per-second rates (successive-snapshot diffs); `--once` prints
/// a single cumulative frame and exits non-zero unless the profile block
/// is present and well-formed.
fn cmd_top(args: &Args) -> Result<()> {
    args.expect_known(&["addr", "interval-ms", "count"], &["once"])?;
    let addr = args.get_or("addr", "127.0.0.1:7471");
    let interval_ms = args.get_usize("interval-ms", 1000)?.max(10) as u64;
    let count = if args.has("once") { 1 } else { args.get_usize("count", 0)? };
    let mut client = Client::connect_backoff(
        addr.as_str(),
        40,
        Duration::from_millis(50),
        Duration::from_millis(500),
        0,
    )?;
    let mut prev: Option<(Json, Instant)> = None;
    let mut polls = 0usize;
    loop {
        let snap = client.stats_versioned()?;
        let now = Instant::now();
        if polls > 0 {
            println!();
        }
        let window =
            prev.as_ref().map(|(p, t)| (p, now.duration_since(*t).as_secs_f64()));
        render_top(&snap, window)?;
        polls += 1;
        if count > 0 && polls >= count {
            return Ok(());
        }
        prev = Some((snap, now));
        std::thread::sleep(Duration::from_millis(interval_ms));
    }
}

fn help() {
    println!(
        "menage — MENAGE mixed-signal neuromorphic accelerator reproduction

USAGE:
  menage info      --model <nmnist|cifar_small|cifar|cifar_conv>
  menage map       --model M --accel <accel1|accel2|cfg.toml> [--strategy S] [--synthetic]
                   [--expand-conv]
  menage simulate  --model M --accel A [--samples N] [--workers W]
                   [--strategy ilp_flow|ilp_exact|greedy|first_fit|round_robin]
                   [--analog ideal|paper] [--golden] [--synthetic] [--out FILE]
                   [--shards K] [--check-monolithic] [--faults SPEC]
                   [--expand-conv]
  menage waveform  [--out FILE]
  menage serve     --model M --accel A [--synthetic] [--addr HOST:PORT]
                   [--workers W] [--lanes L] [--fill-wait-us U]
                   [--max-in-flight N] [--duration-secs S] [--shards K]
                   [--allow-remote-shutdown] [--strategy S] [--analog A]
                   [--faults SPEC] [--chaos SPEC]
                   [--session-lanes N] [--session-idle-secs S]
                   [--remote-shards HOST:PORT,HOST:PORT,...] [--remote-window W]
  menage shard-host --model M --accel A --shards K --shard-index I
                   [--addr HOST:PORT] [--synthetic] [--strategy S] [--analog A]
                   [--faults SPEC] [--duration-secs S] [--allow-remote-shutdown]
  menage loadgen   [--addr HOST:PORT] [--connections C] [--requests N]
                   [--pipeline P] [--rate R] [--deadline-ms D] [--seed S]
                   [--shards K] [--out BENCH_serve.json] [--shutdown-server]
                   [--profile] [--stream] [--chunk-timesteps T]
  menage top       [--addr HOST:PORT] [--interval-ms MS] [--count N] [--once]

serve/loadgen speak the length-prefixed binary protocol documented in
menage::serve::protocol (and README.md); loadgen prints a latency/
throughput table and writes BENCH_serve.json.

menage top polls the server's versioned STATS snapshot every
--interval-ms (default 1000) and renders the observability plane: the
per-stage trace-span histograms (admit/queue/dispatch/step/egress), the
per-core and per-shard execution counters (windowed per-second rates from
the second poll on), remote-link gauges on distributed pipelines, and the
slowest retained traces. --once prints a single cumulative frame (and
fails unless the profile block is present); --count N stops after N
polls. loadgen --profile records the same breakdown into BENCH_serve.json
(server stage histograms for client-vs-server latency attribution, plus
this run's per-core/per-shard execution-counter delta).

Streaming sessions: serve pins one chip lane per open session
(--session-lanes, default 8) whose membrane state persists across
SESSION_CHUNK frames — a chunked train answers bit-identically to a
one-shot INFER over the concatenated train. Idle sessions are evicted
after --session-idle-secs (default 60), folding their stats back into
the chip totals. loadgen --stream drives this path: each request becomes
a session streamed in --chunk-timesteps-step chunks (default 4),
reporting per-chunk latency and sustained events/s, and checking the
server's running prediction against a client-side fold of the chunk
outputs.

--shards K partitions the layer pipeline across K chips (ILP/DP cut
minimizing inter-shard spike traffic under per-chip capacity), with
boundary spike frontiers forwarded chip-to-chip each time step —
bit-identical to monolithic execution (simulate --check-monolithic
asserts it end-to-end; loadgen --shards K asserts the server topology).

Distributed shards: start one `shard-host` per shard (same --model,
--shards, --faults and seed on every host, distinct --shard-index), then
point a driver at them with --remote-shards HOST:PORT,... (pipeline
order). serve --remote-shards fronts the distributed pipeline with the
usual TCP inference service; simulate --remote-shards drives it directly
and --check-monolithic asserts bit-identity against a local oracle.
--remote-window W bounds timesteps in flight per link (default 2).

--model cifar_conv is a compressed convolutional stack (2×32×32 events →
8×16×16 → 8×8×8 → 10 classes): conv layers store one kernel each and the
engine generates synapse rows arithmetically per spike (synapse
compression), instead of an expanded out_dim×in_dim table. --expand-conv
densifies those layers into the expanded oracle representation — same
classification and cycles, vastly larger weight SRAM footprint — for A/B
comparisons of memory and shard counts (serve/shard-host accept it too).

--faults injects deterministic analog hardware faults, e.g.
  --faults seed=3,stuck=0.05,dead=0.02,flip=0.001,drift=1.2
(stuck C2C ladder rows, dead op-amp neuron slots, transient event-id bit
flips, analog drift scaling). simulate reports accuracy degradation vs a
fault-free oracle; serve exposes per-counter totals in STATS.

--chaos injects serving-layer failures, e.g.
  --chaos panic=50,drop=100,delay=200,delay_ms=20,reset=300
(worker panics every Nth batch, dropped/delayed responses, connection
resets mid-frame). The server self-heals: panicked workers are respawned
and their requests resubmitted once; loadgen retries lost responses and
reconnects torn connections, failing only on terminal loss.

Run `make artifacts` first to produce trained weights + HLO under artifacts/,
or pass --synthetic to run on a generated network."
    );
}

fn main() {
    let args = match Args::parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let r = match args.cmd.as_str() {
        "info" => cmd_info(&args),
        "map" => cmd_map(&args),
        "simulate" => cmd_simulate(&args),
        "waveform" => cmd_waveform(&args),
        "serve" => cmd_serve(&args),
        "shard-host" => cmd_shard_host(&args),
        "loadgen" => cmd_loadgen(&args),
        "top" => cmd_top(&args),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            help();
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_kv_and_flags() {
        let a = Args::parse_from(argv(&[
            "simulate", "--model", "nmnist", "--samples", "12", "--synthetic",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "simulate");
        assert_eq!(a.get("model"), Some("nmnist"));
        assert_eq!(a.get_usize("samples", 0).unwrap(), 12);
        assert!(a.has("synthetic"));
        assert_eq!(a.get_or("accel", "accel1"), "accel1");
    }

    #[test]
    fn parse_rejects_non_dashed() {
        assert!(Args::parse_from(argv(&["map", "nmnist"])).is_err());
    }

    /// The regression this guards: a typo'd flag used to be silently
    /// ignored, so the run proceeded with defaults instead of erroring.
    #[test]
    fn unknown_options_and_flags_are_errors() {
        let vocab_keys = ["model", "samples"];
        let vocab_flags = ["synthetic"];
        // Typo'd option (`--sample` for `--samples`).
        let a = Args::parse_from(argv(&["simulate", "--sample", "12"])).unwrap();
        let e = a.expect_known(&vocab_keys, &vocab_flags).unwrap_err();
        assert!(e.to_string().contains("--sample"), "{e}");
        // Typo'd flag.
        let a = Args::parse_from(argv(&["simulate", "--synthettic"])).unwrap();
        assert!(a.expect_known(&vocab_keys, &vocab_flags).is_err());
        // Valid vocabulary passes.
        let a = Args::parse_from(argv(&["simulate", "--samples", "4", "--synthetic"])).unwrap();
        a.expect_known(&vocab_keys, &vocab_flags).unwrap();
        // An option given without a value reads as a flag → specific error.
        let a = Args::parse_from(argv(&["simulate", "--samples"])).unwrap();
        let e = a.expect_known(&vocab_keys, &vocab_flags).unwrap_err();
        assert!(e.to_string().contains("requires a value"), "{e}");
    }

    /// Every real subcommand's vocabulary check must reject a stray flag
    /// (the handlers call expect_known before doing any work).
    #[test]
    fn subcommand_handlers_reject_unknown_flags() {
        for cmd in
            ["info", "map", "simulate", "waveform", "serve", "shard-host", "loadgen", "top"]
        {
            let a = Args::parse_from(argv(&[cmd, "--definitely-not-a-flag"])).unwrap();
            let r = match cmd {
                "info" => cmd_info(&a),
                "map" => cmd_map(&a),
                "simulate" => cmd_simulate(&a),
                "waveform" => cmd_waveform(&a),
                "serve" => cmd_serve(&a),
                "shard-host" => cmd_shard_host(&a),
                "loadgen" => cmd_loadgen(&a),
                "top" => cmd_top(&a),
                _ => unreachable!(),
            };
            let e = r.unwrap_err();
            assert!(
                e.to_string().contains("definitely-not-a-flag"),
                "{cmd}: wrong error: {e}"
            );
        }
    }
}
