//! `menage` — CLI for the MENAGE accelerator reproduction.
//!
//! Subcommands (clap is not in the offline vendor set; args are parsed by
//! the in-tree parser below):
//!
//! ```text
//! menage simulate  --model nmnist --accel accel1 [--samples N] [--workers W]
//!                  [--strategy ilp_flow|greedy|first_fit|round_robin]
//!                  [--analog ideal|paper] [--golden] [--synthetic]
//! menage map       --model nmnist --accel accel1 [--strategy S]
//! menage waveform  [--out waveform.json]
//! menage info      --model nmnist
//! ```
//!
//! `simulate` is the end-to-end driver: load the python-trained weights
//! (or generate a synthetic network with `--synthetic`), ILP-map onto the
//! accelerator, run the eval split through the cycle-accurate simulator
//! via the multi-worker coordinator, and report accuracy, cycles, and
//! TOPS/W. `--golden` additionally loads the JAX-lowered HLO through PJRT
//! and cross-checks predictions.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use menage::accel::Menage;
use menage::analog::AnalogParams;
use menage::bench::Table;
use menage::config::{AcceleratorConfig, ModelConfig};
use menage::coordinator::Coordinator;
use menage::datasets::{Dataset, DatasetKind};
use menage::energy::{report, EnergyModel};
use menage::mapping::{map_network, Strategy};
use menage::runtime::{artifacts_dir, cpu_client, pjrt_available, GoldenModel};
use menage::snn::{QuantNetwork, SpikeTrain};
use menage::trace::MemoryTrace;
use menage::util::json::Json;
use menage::util::rng::Rng;
use menage::util::tensorfile::TensorFile;

/// Minimal `--key value` / `--flag` argument parser.
struct Args {
    cmd: String,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = BTreeMap::new();
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --option, got {a:?}"))?;
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(key.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Self { cmd, kv, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

/// Resolve a model name to its config + dataset kind + artifact base name.
fn resolve_model(name: &str) -> Result<(ModelConfig, DatasetKind, &'static str)> {
    Ok(match name {
        "nmnist" => (ModelConfig::nmnist_mlp(), DatasetKind::NMnist, "nmnist"),
        "cifar_small" | "cifar10dvs_small" => (
            ModelConfig::cifar10dvs_mlp_small(),
            DatasetKind::Cifar10DvsSmall,
            "cifar_small",
        ),
        "cifar" | "cifar10dvs" => {
            (ModelConfig::cifar10dvs_mlp(), DatasetKind::Cifar10Dvs, "cifar")
        }
        _ => bail!("unknown model {name:?} (nmnist | cifar_small | cifar)"),
    })
}

fn resolve_accel(name: &str) -> Result<AcceleratorConfig> {
    Ok(match name {
        "accel1" => AcceleratorConfig::accel1(),
        "accel2" => AcceleratorConfig::accel2(),
        path => AcceleratorConfig::from_file(path)
            .with_context(|| format!("--accel {path:?} is neither a preset nor a config file"))?,
    })
}

/// Load the trained network from artifacts, or synthesize one.
fn load_network(base: &str, mcfg: &ModelConfig, synthetic: bool) -> Result<QuantNetwork> {
    if synthetic {
        let mut rng = Rng::new(7);
        return Ok(QuantNetwork::random(mcfg, 0.5, &mut rng));
    }
    let path = artifacts_dir().join(format!("{base}.weights.mtz"));
    let tf = TensorFile::load(&path).with_context(|| {
        format!(
            "loading {} — run `make artifacts` first or pass --synthetic",
            path.display()
        )
    })?;
    QuantNetwork::from_tensorfile(base, &tf)
}

/// Load the eval split exported by aot.py: (inputs, labels, golden counts).
fn load_eval(base: &str, limit: usize) -> Result<Vec<(SpikeTrain, usize, Vec<f32>)>> {
    let path = artifacts_dir().join(format!("{base}.eval.mtz"));
    let tf = TensorFile::load(&path)?;
    let ev = tf.get("events")?;
    let dims = ev.dims().to_vec(); // [n, T, dim]
    if dims.len() != 3 {
        bail!("events tensor must be 3-D");
    }
    let data = ev.as_u8()?;
    let labels = tf.get("labels")?.as_i32()?;
    let golden = tf.get("golden_counts")?.as_f32()?;
    let (n, t, d) = (dims[0].min(limit), dims[1], dims[2]);
    let classes = golden.len() / dims[0];
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut st = SpikeTrain::new(d, t);
        for (ti, step) in st.spikes.iter_mut().enumerate() {
            let row = &data[i * t * d + ti * d..i * t * d + (ti + 1) * d];
            for (j, &v) in row.iter().enumerate() {
                if v != 0 {
                    step.push(j as u32);
                }
            }
        }
        out.push((
            st,
            labels[i] as usize,
            golden[i * classes..(i + 1) * classes].to_vec(),
        ));
    }
    Ok(out)
}

fn cmd_info(args: &Args) -> Result<()> {
    let (mcfg, kind, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    println!("model: {}", mcfg.name);
    println!("  layers:     {:?}", mcfg.layer_sizes);
    println!("  params:     {}", mcfg.num_params());
    println!("  timesteps:  {}", mcfg.timesteps);
    println!("  dataset:    {} (input dim {})", kind.name(), kind.input_dim());
    if let Ok(net) = load_network(base, &mcfg, false) {
        println!("  trained artifact: {} nnz / sparsity {:.2}", net.nnz(), net.sparsity());
    } else {
        println!("  trained artifact: not found (run `make artifacts`)");
    }
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    let (mcfg, _, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    let cfg = resolve_accel(&args.get_or("accel", "accel1"))?;
    let strategy = Strategy::parse(&args.get_or("strategy", "ilp_flow"))?;
    let net = load_network(base, &mcfg, args.has("synthetic"))?;
    let t0 = std::time::Instant::now();
    let mappings = map_network(&net, &cfg, strategy)?;
    let dt = t0.elapsed();
    let mut table = Table::new(
        format!("{} on {} via {}", net.name, cfg.name, strategy.name()),
        &["layer", "neurons", "rounds", "assigned", "unassigned", "peak load"],
    );
    for (l, (mp, layer)) in mappings.iter().zip(&net.layers).enumerate() {
        mp.validate(layer, &cfg)?;
        table.row(&[
            l.to_string(),
            layer.out_dim.to_string(),
            mp.rounds.len().to_string(),
            mp.assigned_count().to_string(),
            mp.unassigned.len().to_string(),
            mp.peak_engine_load(layer, cfg.a_neurons_per_core).to_string(),
        ]);
    }
    table.print();
    println!("mapping time: {dt:?}");
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (mcfg, kind, base) = resolve_model(&args.get_or("model", "nmnist"))?;
    let cfg = resolve_accel(&args.get_or("accel", "accel1"))?;
    let strategy = Strategy::parse(&args.get_or("strategy", "ilp_flow"))?;
    let analog = match args.get_or("analog", "ideal").as_str() {
        "ideal" => AnalogParams::ideal(),
        "paper" => AnalogParams::paper(),
        other => bail!("--analog must be ideal|paper, got {other:?}"),
    };
    let workers = args.get_usize("workers", 4)?;
    let samples = args.get_usize("samples", 40)?;
    let synthetic = args.has("synthetic");

    let net = load_network(base, &mcfg, synthetic)?;
    println!(
        "loaded {}: {} params, {} nnz (sparsity {:.2}), T={}",
        net.name,
        net.num_params(),
        net.nnz(),
        net.sparsity(),
        net.timesteps
    );
    let chip = Menage::build(&net, &cfg, strategy, &analog, 7)?;
    for (l, core) in chip.cores.iter().enumerate() {
        println!(
            "  core {l}: {} rounds, {} SN rows, {} weight bytes",
            core.rounds(),
            core.image_sn_rows(),
            core.weight_bytes()
        );
    }

    // Inputs: trained eval split or synthetic events.
    let eval = if synthetic {
        let ds = Dataset::new(kind, 3, net.timesteps);
        ds.balanced_split(samples, 0)
            .into_iter()
            .map(|s| (s.events, s.label, vec![]))
            .collect()
    } else {
        load_eval(base, samples)?
    };
    println!("running {} samples on {} workers…", eval.len(), workers);

    let mut coord = Coordinator::new(&chip, workers);
    let t0 = std::time::Instant::now();
    let batch: Vec<(SpikeTrain, Option<usize>)> = eval
        .iter()
        .map(|(st, label, _)| (st.clone(), Some(*label)))
        .collect();
    let responses = coord.run_batch(batch)?;
    let wall = t0.elapsed();

    // Optional golden cross-check through PJRT (skipped, not fatal, on a
    // build without the `pjrt` feature).
    let mut golden_agree = None;
    if args.has("golden") && !pjrt_available() {
        eprintln!("--golden skipped: built without the `pjrt` cargo feature");
    } else if args.has("golden") {
        let client = cpu_client()?;
        let hlo = artifacts_dir().join(format!("{base}.hlo.txt"));
        let gm = GoldenModel::load(
            &client,
            &hlo,
            net.timesteps,
            net.input_dim(),
            net.output_dim(),
        )?;
        let mut agree = 0usize;
        for ((st, _, _), resp) in eval.iter().zip(&responses) {
            if gm.predict(st)? == resp.predicted {
                agree += 1;
            }
        }
        golden_agree = Some(agree as f64 / eval.len() as f64);
    }

    let chips = coord.shutdown();
    // Merge stats from all workers into one report.
    let merged = merge_chips(chips);
    let model = EnergyModel::paper_90nm(cfg.clock_hz);
    let eff = report(&merged, &model);
    let trace = MemoryTrace::from_chip(&merged, kind.name(), net.timesteps, eval.len());

    println!("\n== results ==");
    println!("accuracy:        {:.4}", merged_accuracy(&responses));
    if let Some(g) = golden_agree {
        println!("golden agreement: {g:.4} (simulator vs PJRT-executed JAX model)");
    }
    println!("wall time:       {wall:?} ({:.1} samples/s)", eval.len() as f64 / wall.as_secs_f64());
    println!("modeled cycles:  {} ({:.3} ms at {:.1} MHz)",
        responses.iter().map(|r| r.cycles).sum::<u64>(),
        responses.iter().map(|r| r.cycles).sum::<u64>() as f64 * cfg.clock_period() * 1e3,
        cfg.clock_hz / 1e6);
    println!("total MACs:      {}", merged.total_macs());
    println!("energy:          {:.3} µJ", eff.breakdown.total() * 1e6);
    println!("TOPS/W:          {:.2}", eff.tops_per_watt);
    println!("MEM_S&N mean:    {:.1} KB (peak {:.1} KB)", trace.mean_kb(), trace.peak_kb());

    if let Some(out) = args.get("out") {
        let j = Json::obj(vec![
            ("accuracy", merged_accuracy(&responses).into()),
            ("tops_per_watt", eff.tops_per_watt.into()),
            ("total_macs", (merged.total_macs() as usize).into()),
            ("trace", trace.to_json()),
        ]);
        std::fs::write(out, j.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn merged_accuracy(responses: &[menage::coordinator::Response]) -> f64 {
    let labelled = responses.iter().filter(|r| r.label.is_some()).count();
    if labelled == 0 {
        return f64::NAN;
    }
    responses
        .iter()
        .filter(|r| r.label == Some(r.predicted))
        .count() as f64
        / labelled as f64
}

/// Merge per-worker chips into one stats carrier (stats are additive).
fn merge_chips(mut chips: Vec<Menage>) -> Menage {
    let mut base = chips.remove(0);
    for other in chips {
        for (a, b) in base.cores.iter_mut().zip(other.cores) {
            a.stats.cycles += b.stats.cycles;
            a.stats.events_dispatched += b.stats.events_dispatched;
            a.stats.sn_rows_read += b.stats.sn_rows_read;
            a.stats.macs += b.stats.macs;
            a.stats.integrations += b.stats.integrations;
            a.stats.fire_ops += b.stats.fire_ops;
            a.stats.spikes_out += b.stats.spikes_out;
            a.stats.dropped_events += b.stats.dropped_events;
            a.stats
                .sn_rows_touched_per_step
                .extend(b.stats.sn_rows_touched_per_step);
            a.stats.cycles_per_step.extend(b.stats.cycles_per_step);
        }
        base.inputs_processed += other.inputs_processed;
    }
    base
}

fn cmd_waveform(args: &Args) -> Result<()> {
    use menage::analog::ANeuron;
    let mut an = ANeuron::new(1, AnalogParams::paper());
    an.enable_capture();
    let mut rng = Rng::new(11);
    for _ in 0..40 {
        let packet = if rng.bernoulli(0.7) { rng.uniform(0.1, 0.5) } else { 0.0 };
        an.process(0, packet, 1.0, 0.0);
        an.lif_leak(0.9);
    }
    let wf = an.waveform();
    println!("captured {} waveform points over {:.1} ns", wf.len(), an.now * 1e9);
    println!("average power: {:.1} nW (paper: 97 nW)", an.average_power() * 1e9);
    if let Some(out) = args.get("out") {
        let j = Json::Arr(
            wf.iter()
                .map(|p| {
                    Json::obj(vec![
                        ("t", p.t.into()),
                        ("v_in", p.v_in.into()),
                        ("v_integ", p.v_integ.into()),
                        ("v_out", p.v_out.into()),
                    ])
                })
                .collect(),
        );
        std::fs::write(out, j.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn help() {
    println!(
        "menage — MENAGE mixed-signal neuromorphic accelerator reproduction

USAGE:
  menage info      --model <nmnist|cifar_small|cifar>
  menage map       --model M --accel <accel1|accel2|cfg.toml> [--strategy S] [--synthetic]
  menage simulate  --model M --accel A [--samples N] [--workers W]
                   [--strategy ilp_flow|ilp_exact|greedy|first_fit|round_robin]
                   [--analog ideal|paper] [--golden] [--synthetic] [--out FILE]
  menage waveform  [--out FILE]

Run `make artifacts` first to produce trained weights + HLO under artifacts/."
    );
}

fn main() {
    let args = match Args::parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let r = match args.cmd.as_str() {
        "info" => cmd_info(&args),
        "map" => cmd_map(&args),
        "simulate" => cmd_simulate(&args),
        "waveform" => cmd_waveform(&args),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            help();
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
