//! Multi-chip pipeline-parallel sharding.
//!
//! A [`ShardedMenage`] runs one model across several MENAGE chips: the
//! layer chain is split into contiguous **shards** by the ILP/DP
//! partitioner ([`crate::mapping::partition_layers`], minimizing
//! inter-shard spike traffic under per-chip core/memory capacity), each
//! shard is a full [`Menage`] chip, and per global time step every shard
//! consumes its predecessor's boundary [`SpikeTrain`] frontier — the same
//! intra-step forward propagation the cores inside one chip use, lifted to
//! the chip-to-chip links.
//!
//! **Bit-identity.** Sharded execution is pinned bit-identical to
//! [`Menage::run`] (output trains, modeled cycles, per-core `CoreStats`)
//! by `tests/shard_differential.rs`, and the equivalence is structural
//! rather than coincidental:
//!
//! * every core is built in **monolithic order from one RNG stream**
//!   (identical images, identical non-ideal C2C mismatch draws), then the
//!   chain is split into per-shard chips via [`Menage::from_cores`];
//! * the run loop visits (shard, core) pairs in exactly the global layer
//!   order of the monolithic chip, forwarding each boundary frontier
//!   within the step — the same dataflow, so the same arithmetic in ideal
//!   *and* non-ideal analog mode;
//! * modeled cycles take the per-step max across **all** cores of **all**
//!   shards, modeling chips on one synchronous clock (exactly the
//!   monolithic cost model).
//!
//! Because sharded chips each host at most `num_cores` layers, a sharded
//! system can carry models **deeper than one chip allows** — the
//! capacity-scaling case `tests/shard_differential.rs` pins against the
//! reference model (no monolithic chip exists to compare with there).

use anyhow::{bail, Result};

use crate::accel::{Menage, RunOutput};
use crate::analog::AnalogParams;
use crate::config::AcceleratorConfig;
use crate::mapping::{
    distill_network, map_layer, partition_layers, shard_cut_costs, ShardLimits, ShardPlan,
    Strategy,
};
use crate::neuracore::NeuraCore;
use crate::snn::{QuantNetwork, SpikeTrain};
use crate::util::json::Json;

/// A pipeline of MENAGE chips executing one model (module docs).
#[derive(Debug, Clone)]
pub struct ShardedMenage {
    /// One chip per shard, in pipeline order; shard `s` hosts the
    /// contiguous layer range `plan.ranges()[s]`.
    pub shards: Vec<Menage>,
    pub plan: ShardPlan,
    /// Estimated traffic cost of each chosen cut (`len = shards − 1`),
    /// from [`shard_cut_costs`].
    pub boundary_cost: Vec<u64>,
    /// Spikes actually forwarded across each cut so far (`len = shards −
    /// 1`) — the observable the partitioner's estimate is judged against.
    pub boundary_events: Vec<u64>,
    pub timesteps: usize,
    pub inputs_processed: u64,
    step_scratch: Vec<u32>,
    lane_scratch: Vec<Vec<u32>>,
    lane_prev_cycles: Vec<u64>,
}

/// Number of **distinct** sources in an event slice — the quantity a
/// chip-to-chip link actually carries. `engine::step` coalesces duplicate
/// sources into one row fetch with a multiplicity, and a wire frontier is
/// a spike *set* per step, so counting `len()` at a cut overstates
/// boundary traffic relative to the [`shard_cut_costs`] estimate the
/// partitioner optimizes whenever duplicates reach the cut. Cut frontiers
/// are core outputs today (sorted, already distinct — the O(1) fast
/// path), but the accounting must stay honest for event sources that
/// repeat, e.g. future compressed-conv layers emitting per-tap events.
pub(crate) fn distinct_sources(events: &[u32]) -> u64 {
    if events.windows(2).all(|w| w[0] < w[1]) {
        // Strictly ascending (or empty / single): every entry distinct.
        return events.len() as u64;
    }
    if events.windows(2).all(|w| w[0] <= w[1]) {
        // Sorted with duplicate runs: distinct sources = run starts.
        return 1 + events.windows(2).filter(|w| w[0] != w[1]).count() as u64;
    }
    // Unsorted (duplicate-heavy raw injections): count via sort+dedup.
    let mut v = events.to_vec();
    v.sort_unstable();
    v.dedup();
    v.len() as u64
}

impl ShardedMenage {
    /// Map, distill, and load `net` onto `num_shards` chips described by
    /// `cfg`. `num_shards` is clamped to the layer count (a shard cannot
    /// be empty), so `shards > layers` degrades gracefully to one layer
    /// per chip and `num_shards = 1` is exactly a monolithic build.
    ///
    /// Unlike [`Menage::build`], the pipeline may be **deeper than one
    /// chip**: the only per-chip limit is `cfg.num_cores` layers per
    /// shard (enforced by the partitioner).
    pub fn build(
        net: &QuantNetwork,
        cfg: &AcceleratorConfig,
        strategy: Strategy,
        analog: &AnalogParams,
        seed: u64,
        num_shards: usize,
    ) -> Result<Self> {
        cfg.validate()?;
        net.validate()?;
        if num_shards == 0 {
            bail!("cannot run on 0 shards");
        }
        let k = num_shards.min(net.layers.len());
        let plan = partition_layers(net, k, &ShardLimits::from_accel(cfg))?;
        // Per-layer mapping exactly as the monolithic build performs it
        // (map_network is map_layer per layer plus a chip-level core-count
        // check that sharding deliberately relaxes).
        let mappings = net
            .layers
            .iter()
            .map(|l| map_layer(l, cfg, strategy))
            .collect::<Result<Vec<_>>>()?;
        for (mp, layer) in mappings.iter().zip(&net.layers) {
            mp.validate(layer, cfg)?;
        }
        let images = distill_network(net, &mappings, cfg)?;
        // The literal monolithic constructor builds the whole core chain
        // (one RNG stream in layer order — identical non-ideal mismatch
        // draws), so bit-identity to `Menage::build` holds by
        // construction, not by keeping two loops in sync.
        let chain = Menage::from_images(net, cfg, images, analog, seed)?;
        Self::from_core_chain(cfg, chain.cores, net.timesteps, plan, shard_cut_costs(net))
    }

    /// Split a monolithic-order core chain into per-shard chips.
    fn from_core_chain(
        cfg: &AcceleratorConfig,
        mut cores: Vec<NeuraCore>,
        timesteps: usize,
        plan: ShardPlan,
        all_cut_costs: Vec<u64>,
    ) -> Result<Self> {
        if cores.len() != plan.shard_of.len() {
            bail!("{} cores for a {}-layer plan", cores.len(), plan.shard_of.len());
        }
        let boundary_cost: Vec<u64> =
            plan.cuts().iter().map(|&b| all_cut_costs[b]).collect();
        let mut shards = Vec::with_capacity(plan.num_shards);
        for range in plan.ranges().into_iter().rev() {
            let tail = cores.split_off(range.start);
            shards.push(Menage::from_cores(cfg, tail, timesteps)?);
        }
        shards.reverse();
        let cuts = plan.num_shards - 1;
        Ok(Self {
            shards,
            plan,
            boundary_cost,
            boundary_events: vec![0; cuts],
            timesteps,
            inputs_processed: 0,
            step_scratch: Vec::new(),
            lane_scratch: Vec::new(),
            lane_prev_cycles: Vec::new(),
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn num_layers(&self) -> usize {
        self.shards.iter().map(|s| s.cores.len()).sum()
    }

    pub fn input_dim(&self) -> usize {
        self.shards[0].cores[0].in_dim()
    }

    pub fn output_dim(&self) -> usize {
        self.shards.last().unwrap().cores.last().unwrap().out_dim()
    }

    /// Reassemble the pipeline into one monolithic-shaped [`Menage`]
    /// carrying every core's accumulated stats — the stats carrier the
    /// coordinator hands back at shutdown so `merge_chips`, the energy
    /// report, and the trace figures are shard-agnostic.
    pub fn into_monolithic(self) -> Menage {
        let timesteps = self.timesteps;
        let inputs = self.inputs_processed;
        let mut shards = self.shards.into_iter();
        let mut base = shards.next().expect("sharded chip has ≥1 shard");
        for shard in shards {
            base.cores.extend(shard.cores);
        }
        let mut chip = Menage::from_cores(&base.config, base.cores, timesteps)
            .expect("non-empty core chain");
        chip.inputs_processed = inputs;
        chip
    }

    /// Run one input through the pipeline (fresh [`RunOutput`]); see
    /// [`Self::run_into`].
    pub fn run(&mut self, input: &SpikeTrain) -> Result<RunOutput> {
        let mut out = RunOutput::default();
        self.run_into(input, &mut out)?;
        Ok(out)
    }

    /// [`Menage::run_into`] semantics across chips: per global time step
    /// the shards execute in pipeline order, each consuming its
    /// predecessor's boundary frontier of the same step (`trains[l−1]` at
    /// the cut is exactly the `SpikeTrain` frontier a chip-to-chip link
    /// would carry). Bit-identical to the monolithic chip by construction
    /// — same cores, same visit order, same double-buffered scratch
    /// discipline.
    pub fn run_into(&mut self, input: &SpikeTrain, out: &mut RunOutput) -> Result<()> {
        self.run_chunk_into(input, false, out)
    }

    /// MIRROR of [`Menage::run_chunk_into`] across chips: run one chunk of
    /// a longer event stream, suspending/resuming every core's membrane
    /// state (instead of resetting) when `resume` — so a train split at
    /// arbitrary chunk boundaries is bit-identical to one [`Self::run_into`]
    /// on the concatenated train, including `boundary_events` accounting
    /// (the cut frontier of a chunk seam is the same frontier the one-shot
    /// run forwards at that step). Pinned by `tests/stream_differential.rs`.
    pub fn run_chunk_into(
        &mut self,
        input: &SpikeTrain,
        resume: bool,
        out: &mut RunOutput,
    ) -> Result<()> {
        if input.num_neurons != self.input_dim() {
            bail!(
                "input has {} neurons, first shard expects {}",
                input.num_neurons,
                self.input_dim()
            );
        }
        let t_steps = input.timesteps();
        let total = self.num_layers();
        out.trains.resize_with(total, SpikeTrain::default);
        {
            let mut l = 0usize;
            for shard in self.shards.iter_mut() {
                for core in shard.cores.iter_mut() {
                    if !resume {
                        core.reset_membranes();
                    }
                    out.trains[l].reset_to(core.out_dim(), t_steps);
                    l += 1;
                }
            }
        }
        out.cycles = 0;
        let shards = &mut self.shards;
        let scratch = &mut self.step_scratch;
        let boundary_events = &mut self.boundary_events;
        for t in 0..t_steps {
            // Chips share one synchronous clock: the step's wall cycles
            // are set by the busiest core of the busiest shard.
            let mut step_cycles = 0u64;
            let mut l = 0usize;
            for (si, shard) in shards.iter_mut().enumerate() {
                for (ci, core) in shard.cores.iter_mut().enumerate() {
                    {
                        let events: &[u32] = if l == 0 {
                            &input.spikes[t]
                        } else {
                            &out.trains[l - 1].spikes[t]
                        };
                        if ci == 0 && si > 0 {
                            // The frontier just crossed a chip boundary:
                            // count distinct sources, i.e. wire spikes.
                            boundary_events[si - 1] += distinct_sources(events);
                        }
                        core.push_events(events);
                    }
                    let before = core.stats.cycles;
                    core.step_into(scratch);
                    step_cycles = step_cycles.max(core.stats.cycles - before);
                    std::mem::swap(&mut out.trains[l].spikes[t], scratch);
                    l += 1;
                }
            }
            out.cycles += step_cycles;
        }
        if !resume {
            self.inputs_processed += 1;
        }
        Ok(())
    }

    /// Lane-batched pipeline execution (fresh output vector); see
    /// [`Self::run_lanes_into`].
    pub fn run_lanes(&mut self, inputs: &[SpikeTrain]) -> Result<Vec<RunOutput>> {
        let mut outs = Vec::new();
        self.run_lanes_into(inputs, &mut outs)?;
        Ok(outs)
    }

    /// [`Menage::run_lanes_into`] across chips: every shard's cores carry
    /// the batch as SIMD lanes, boundary frontiers are forwarded
    /// shard-to-shard per (step, lane), and per-lane outputs/stats stay
    /// bit-identical to sequential monolithic runs (same unified engine,
    /// same visit order — pinned by `tests/shard_differential.rs`).
    pub fn run_lanes_into(
        &mut self,
        inputs: &[SpikeTrain],
        outs: &mut Vec<RunOutput>,
    ) -> Result<()> {
        for (i, input) in inputs.iter().enumerate() {
            if input.num_neurons != self.input_dim() {
                bail!(
                    "lane {i}: input has {} neurons, first shard expects {}",
                    input.num_neurons,
                    self.input_dim()
                );
            }
        }
        let b = inputs.len();
        outs.resize_with(b, RunOutput::default);
        if b == 0 {
            return Ok(());
        }
        let total = self.num_layers();
        for shard in self.shards.iter_mut() {
            for core in shard.cores.iter_mut() {
                core.ensure_lanes(b);
                core.reset_lanes();
            }
        }
        for (i, out) in outs.iter_mut().enumerate() {
            let t_i = inputs[i].timesteps();
            out.trains.resize_with(total, SpikeTrain::default);
            let mut l = 0usize;
            for shard in self.shards.iter() {
                for core in shard.cores.iter() {
                    out.trains[l].reset_to(core.out_dim(), t_i);
                    l += 1;
                }
            }
            out.cycles = 0;
        }
        let t_max = inputs.iter().map(|s| s.timesteps()).max().unwrap_or(0);

        let shards = &mut self.shards;
        let scratch = &mut self.lane_scratch;
        scratch.resize_with(b, Vec::new);
        let prev = &mut self.lane_prev_cycles;
        prev.resize(b, 0);
        let boundary_events = &mut self.boundary_events;
        let mut active: Vec<usize> = Vec::with_capacity(b);
        let mut step_cycles = vec![0u64; b];
        for t in 0..t_max {
            active.clear();
            active.extend((0..b).filter(|&i| t < inputs[i].timesteps()));
            for c in step_cycles.iter_mut() {
                *c = 0;
            }
            let mut l = 0usize;
            for (si, shard) in shards.iter_mut().enumerate() {
                for (ci, core) in shard.cores.iter_mut().enumerate() {
                    for (ai, &i) in active.iter().enumerate() {
                        let events: &[u32] = if l == 0 {
                            &inputs[i].spikes[t]
                        } else {
                            &outs[i].trains[l - 1].spikes[t]
                        };
                        if ci == 0 && si > 0 {
                            // MIRROR of run_into: distinct sources only.
                            boundary_events[si - 1] += distinct_sources(events);
                        }
                        core.push_events_lane(i, events);
                        prev[ai] = core.lane_stats(i).cycles;
                    }
                    core.step_lanes_into(&active, &mut scratch[..active.len()]);
                    for (ai, &i) in active.iter().enumerate() {
                        let delta = core.lane_stats(i).cycles - prev[ai];
                        step_cycles[i] = step_cycles[i].max(delta);
                        std::mem::swap(&mut outs[i].trains[l].spikes[t], &mut scratch[ai]);
                    }
                    l += 1;
                }
            }
            for &i in &active {
                outs[i].cycles += step_cycles[i];
            }
        }
        self.inputs_processed += b as u64;
        Ok(())
    }

    /// MIRROR of [`Menage::open_session_lane`] across chips: prepare lane
    /// `lane` on every shard's cores to host a streaming session.
    pub fn open_session_lane(&mut self, lane: usize) {
        for shard in self.shards.iter_mut() {
            for core in shard.cores.iter_mut() {
                core.ensure_lanes(lane + 1);
                core.reset_lane(lane);
            }
        }
        self.inputs_processed += 1;
    }

    /// MIRROR of [`Menage::fold_session_lane`] across chips.
    pub fn fold_session_lane(&mut self, lane: usize) {
        for shard in self.shards.iter_mut() {
            shard.fold_session_lane(lane);
        }
    }

    /// MIRROR of [`Menage::run_session_chunks_into`] across chips: one
    /// chunk per listed session on its resident lane, boundary frontiers
    /// forwarded shard-to-shard per (step, lane) with the same
    /// distinct-source accounting as [`Self::run_lanes_into`], and **no**
    /// lane resets — membrane state carries across chunk seams. Pinned by
    /// `tests/stream_differential.rs`.
    pub fn run_session_chunks_into(
        &mut self,
        jobs: &[(usize, &SpikeTrain)],
        outs: &mut Vec<RunOutput>,
    ) -> Result<()> {
        let opened_lanes = self.shards[0].cores[0].num_lanes();
        for (j, &(lane, chunk)) in jobs.iter().enumerate() {
            if chunk.num_neurons != self.input_dim() {
                bail!(
                    "session lane {lane}: chunk has {} neurons, first shard expects {}",
                    chunk.num_neurons,
                    self.input_dim()
                );
            }
            if j > 0 && jobs[j - 1].0 >= lane {
                bail!("session job lanes must be strictly ascending");
            }
            if lane >= opened_lanes {
                bail!("session lane {lane} was never opened");
            }
        }
        let b = jobs.len();
        outs.resize_with(b, RunOutput::default);
        if b == 0 {
            return Ok(());
        }
        let total = self.num_layers();
        for (j, out) in outs.iter_mut().enumerate() {
            let t_j = jobs[j].1.timesteps();
            out.trains.resize_with(total, SpikeTrain::default);
            let mut l = 0usize;
            for shard in self.shards.iter() {
                for core in shard.cores.iter() {
                    out.trains[l].reset_to(core.out_dim(), t_j);
                    l += 1;
                }
            }
            out.cycles = 0;
        }
        let t_max = jobs.iter().map(|&(_, s)| s.timesteps()).max().unwrap_or(0);

        let shards = &mut self.shards;
        let scratch = &mut self.lane_scratch;
        scratch.resize_with(b, Vec::new);
        let prev = &mut self.lane_prev_cycles;
        prev.resize(b, 0);
        let boundary_events = &mut self.boundary_events;
        let mut active_lanes: Vec<usize> = Vec::with_capacity(b);
        let mut active_jobs: Vec<usize> = Vec::with_capacity(b);
        let mut step_cycles = vec![0u64; b];
        for t in 0..t_max {
            active_lanes.clear();
            active_jobs.clear();
            for (j, &(lane, chunk)) in jobs.iter().enumerate() {
                if t < chunk.timesteps() {
                    active_lanes.push(lane);
                    active_jobs.push(j);
                }
            }
            for c in step_cycles.iter_mut() {
                *c = 0;
            }
            let mut l = 0usize;
            for (si, shard) in shards.iter_mut().enumerate() {
                for (ci, core) in shard.cores.iter_mut().enumerate() {
                    for (ai, &j) in active_jobs.iter().enumerate() {
                        let lane = jobs[j].0;
                        let events: &[u32] = if l == 0 {
                            &jobs[j].1.spikes[t]
                        } else {
                            &outs[j].trains[l - 1].spikes[t]
                        };
                        if ci == 0 && si > 0 {
                            // MIRROR of run_into: distinct sources only.
                            boundary_events[si - 1] += distinct_sources(events);
                        }
                        core.push_events_lane(lane, events);
                        prev[ai] = core.lane_stats(lane).cycles;
                    }
                    core.step_lanes_into(&active_lanes, &mut scratch[..active_lanes.len()]);
                    for (ai, &j) in active_jobs.iter().enumerate() {
                        let delta = core.lane_stats(jobs[j].0).cycles - prev[ai];
                        step_cycles[j] = step_cycles[j].max(delta);
                        std::mem::swap(&mut outs[j].trains[l].spikes[t], &mut scratch[ai]);
                    }
                    l += 1;
                }
            }
            for &j in &active_jobs {
                outs[j].cycles += step_cycles[j];
            }
        }
        Ok(())
    }

    /// Classify a batch sequentially, reusing one [`RunOutput`].
    pub fn run_batch(&mut self, inputs: &[SpikeTrain]) -> Result<Vec<(usize, u64)>> {
        let mut out = RunOutput::default();
        let mut res = Vec::with_capacity(inputs.len());
        for input in inputs {
            self.run_into(input, &mut out)?;
            res.push((out.predicted_class(), out.cycles));
        }
        Ok(res)
    }

    /// Fold lane-attributed statistics into every core's totals (see
    /// [`Menage::fold_lane_stats`]).
    pub fn fold_lane_stats(&mut self) {
        for shard in self.shards.iter_mut() {
            shard.fold_lane_stats();
        }
    }

    /// Install the hardware fault plan on every core of every shard.
    /// Cores keep their monolithic (global layer) index through
    /// [`Menage::from_cores`], so the realized defects are identical to a
    /// monolithic chip under the same plan — sharding does not move the
    /// silicon.
    pub fn install_faults(&mut self, plan: &crate::fault::FaultPlan) {
        for shard in self.shards.iter_mut() {
            shard.install_faults(plan);
        }
    }

    /// Whether any core of any shard carries installed hardware faults.
    pub fn has_faults(&self) -> bool {
        self.shards.iter().any(|s| s.has_faults())
    }

    /// `(stuck_row_hits, dead_slot_hits, events_bit_flipped)` summed over
    /// every shard's cores.
    pub fn fault_counters(&self) -> (u64, u64, u64) {
        let mut t = (0u64, 0u64, 0u64);
        for s in &self.shards {
            let (a, b, c) = s.fault_counters();
            t.0 += a;
            t.1 += b;
            t.2 += c;
        }
        t
    }

    /// Append every core's monotonic execution-profile sample, shard by
    /// shard in global core order (matches [`Self::into_monolithic`]'s
    /// core concatenation) — see [`Menage::profile_samples_into`].
    pub fn profile_samples_into(&self, out: &mut Vec<crate::obs::CoreSample>) {
        for s in &self.shards {
            s.profile_samples_into(out);
        }
    }

    /// `shard_of[c]` for every core in global order — the shard map a
    /// [`crate::obs::ProfilePlane`] is built from.
    pub fn core_shard_map(&self) -> Vec<usize> {
        let mut m = Vec::with_capacity(self.num_layers());
        for (i, s) in self.shards.iter().enumerate() {
            for _ in 0..s.cores.len() {
                m.push(i);
            }
        }
        m
    }

    /// Total analog energy across all shards (J).
    pub fn analog_energy(&self) -> f64 {
        self.shards.iter().map(|s| s.analog_energy()).sum()
    }

    /// Total synaptic MACs across all shards.
    pub fn total_macs(&self) -> u64 {
        self.shards.iter().map(|s| s.total_macs()).sum()
    }

    /// Total events dispatched across all shards.
    pub fn total_events(&self) -> u64 {
        self.shards.iter().map(|s| s.total_events()).sum()
    }

    /// Static shard topology as JSON — the `shards` block the serving
    /// layer's STATS frame reports.
    pub fn shards_json(&self) -> Json {
        Json::Arr(
            self.plan
                .ranges()
                .into_iter()
                .enumerate()
                .map(|(s, range)| {
                    let chip = &self.shards[s];
                    Json::obj(vec![
                        ("shard", s.into()),
                        ("layer_lo", range.start.into()),
                        ("layer_hi", range.end.into()),
                        ("cores", chip.cores.len().into()),
                        ("input_dim", chip.cores[0].in_dim().into()),
                        ("output_dim", chip.cores.last().unwrap().out_dim().into()),
                        (
                            "cut_cost_in",
                            if s == 0 {
                                0usize.into()
                            } else {
                                (self.boundary_cost[s - 1] as usize).into()
                            },
                        ),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::snn::reference_forward;
    use crate::util::rng::Rng;

    fn model(sizes: &[usize], t: usize) -> ModelConfig {
        ModelConfig {
            name: "shard".into(),
            layer_sizes: sizes.to_vec(),
            timesteps: t,
            beta: 0.9,
            v_threshold: 1.0,
            v_reset: 0.0,
        }
    }

    fn accel(cores: usize) -> AcceleratorConfig {
        let mut c = AcceleratorConfig::accel1();
        c.num_cores = cores;
        c.a_neurons_per_core = 4;
        c.a_syns_per_core = 4;
        c.virtual_per_a_neuron = 4;
        c
    }

    fn input(dim: usize, t: usize, rate: f64, seed: u64) -> SpikeTrain {
        let mut rng = Rng::new(seed);
        SpikeTrain::bernoulli(dim, t, rate, &mut rng)
    }

    /// A pipeline deeper than one chip: 5 layers on 2-core chips needs 3
    /// shards and must still match the reference model spike-for-spike.
    #[test]
    fn sharding_hosts_models_deeper_than_one_chip() {
        let mcfg = model(&[20, 14, 10, 8, 6, 4], 6);
        let mut rng = Rng::new(3);
        let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
        let cfg = accel(2);
        // Monolithic build is impossible: 5 layers > 2 cores.
        assert!(Menage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7).is_err());
        let mut sharded =
            ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 3)
                .unwrap();
        assert_eq!(sharded.num_shards(), 3);
        assert_eq!(sharded.num_layers(), 5);
        for seed in 0..4 {
            let st = input(20, 6, 0.25, seed);
            let golden = reference_forward(&net, &st).unwrap();
            let out = sharded.run(&st).unwrap();
            assert!(out.matches_reference(&golden), "seed {seed}");
        }
        assert_eq!(sharded.inputs_processed, 4);
        assert!(sharded.boundary_events.iter().sum::<u64>() > 0, "no boundary traffic seen");
        assert!(sharded.total_macs() > 0);
    }

    #[test]
    fn shards_clamped_to_layers_and_json_shape() {
        let mcfg = model(&[16, 10, 6], 4);
        let mut rng = Rng::new(5);
        let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
        let sharded = ShardedMenage::build(
            &net,
            &accel(4),
            Strategy::IlpFlow,
            &AnalogParams::ideal(),
            7,
            99,
        )
        .unwrap();
        assert_eq!(sharded.num_shards(), 2, "shards > layers must clamp to one layer per shard");
        let j = sharded.shards_json();
        let Json::Arr(arr) = &j else { panic!("shards_json must be an array") };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("layer_lo").unwrap().as_usize().unwrap(), 0);
        assert_eq!(arr[1].get("cut_cost_in").unwrap().as_usize().unwrap() as u64,
                   sharded.boundary_cost[0]);
        assert!(ShardedMenage::build(
            &net,
            &accel(4),
            Strategy::IlpFlow,
            &AnalogParams::ideal(),
            7,
            0
        )
        .is_err());
    }

    #[test]
    fn into_monolithic_reassembles_core_chain() {
        let mcfg = model(&[18, 12, 8, 4], 5);
        let mut rng = Rng::new(8);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let cfg = accel(4);
        let mut sharded =
            ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 2)
                .unwrap();
        let st = input(18, 5, 0.3, 1);
        sharded.run(&st).unwrap();
        let total_macs = sharded.total_macs();
        let chip = sharded.into_monolithic();
        assert_eq!(chip.cores.len(), 3);
        assert_eq!(chip.inputs_processed, 1);
        assert_eq!(chip.total_macs(), total_macs);
        // Core order preserved: in/out dims chain.
        for w in chip.cores.windows(2) {
            assert_eq!(w[0].out_dim(), w[1].in_dim());
        }
    }

    #[test]
    fn distinct_sources_counts_sets_not_events() {
        assert_eq!(distinct_sources(&[]), 0);
        assert_eq!(distinct_sources(&[7]), 1);
        assert_eq!(distinct_sources(&[1, 2, 5, 9]), 4);
        // Sorted duplicate runs collapse to their run starts.
        assert_eq!(distinct_sources(&[1, 1, 1, 2, 5, 5, 9]), 4);
        assert_eq!(distinct_sources(&[3, 3, 3, 3]), 1);
        // Unsorted duplicate-heavy slices (the shape
        // `SpikeTrain::duplicate_events` produces) count set size too.
        assert_eq!(distinct_sources(&[4, 1, 9, 4, 1, 9, 4]), 3);
        assert_eq!(distinct_sources(&[2, 0, 2, 0]), 2);
    }

    /// The regression pinned here: `boundary_events` must equal the number
    /// of *distinct* sources crossing each cut per step — exactly what the
    /// returned cut-layer trains carry — not the raw pushed-event count.
    /// The input is duplicate-heavy (every source fires twice per step),
    /// so any site that counted `events.len()` on a frontier with
    /// duplicates would double-count; the independent recount from the
    /// returned trains is the ground truth.
    #[test]
    fn boundary_events_count_distinct_sources_per_cut() {
        let mcfg = model(&[20, 14, 10, 8, 6, 4], 6);
        let mut rng = Rng::new(3);
        let net = QuantNetwork::random(&mcfg, 0.4, &mut rng);
        let cfg = accel(2);
        let mut sharded =
            ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 3)
                .unwrap();
        let mut st = input(20, 6, 0.3, 5);
        st.duplicate_events(); // duplicates flow through the pipeline
        let out = sharded.run(&st).unwrap();
        let cut_layers: Vec<usize> =
            sharded.plan.ranges()[1..].iter().map(|r| r.start - 1).collect();
        let mut expected = vec![0u64; cut_layers.len()];
        for (c, &cl) in cut_layers.iter().enumerate() {
            for step in &out.trains[cl].spikes {
                expected[c] += distinct_sources(step);
            }
        }
        assert!(expected.iter().sum::<u64>() > 0, "no boundary traffic seen");
        assert_eq!(sharded.boundary_events, expected);

        // MIRROR: the lane path must account identically. Two lanes of the
        // same input double the per-cut counts exactly.
        let mut lanes =
            ShardedMenage::build(&net, &cfg, Strategy::IlpFlow, &AnalogParams::ideal(), 7, 3)
                .unwrap();
        lanes.run_lanes(&[st.clone(), st.clone()]).unwrap();
        let doubled: Vec<u64> = expected.iter().map(|e| e * 2).collect();
        assert_eq!(lanes.boundary_events, doubled);
    }

    #[test]
    fn rejects_wrong_input_width() {
        let mcfg = model(&[12, 8, 4], 3);
        let mut rng = Rng::new(2);
        let net = QuantNetwork::random(&mcfg, 0.5, &mut rng);
        let mut sharded = ShardedMenage::build(
            &net,
            &accel(2),
            Strategy::IlpFlow,
            &AnalogParams::ideal(),
            7,
            2,
        )
        .unwrap();
        assert!(sharded.run(&SpikeTrain::new(99, 3)).is_err());
        assert!(sharded.run_lanes(&[SpikeTrain::new(99, 3)]).is_err());
        assert_eq!(sharded.run_lanes(&[]).unwrap().len(), 0);
    }
}
