//! Cycle-accurate MX-NEURACORE simulator (paper §III, Figures 1 & 4).
//!
//! One MX-NEURACORE executes one model layer. Per global time step the
//! core:
//!
//! 1. latches incoming events into MEM_E on the clock's rising edge;
//! 2. the polling controller pops one event per cycle (unless a previous
//!    event is still being dispatched — "the controller does not fetch any
//!    new event from the MEM_E"), looks up MEM_E2A to find `B_i` MEM_S&N
//!    rows starting at `A_i`;
//! 3. streams those rows, one per cycle: each row drives up to M A-SYN
//!    engines in parallel (C2C MAC) whose charge packets accumulate on the
//!    addressed virtual-neuron capacitors of the M A-NEURONs;
//! 4. at the end of the step the controller sweeps the resident virtual
//!    neurons: leak + integrate + compare-to-threshold → emit spike events
//!    for the next core → reset (the paper's restore/integrate/store plus
//!    the discharge command).
//!
//! Numerics: the charge accumulated during a step is tracked as the exact
//! integer sum of quantized weights (what an ideal C2C ladder deposits);
//! the sweep computes `v ← β·v + Σw·scale` in f32 — *bit-identical* to
//! [`crate::snn::reference_forward`]. Analog non-idealities (C2C mismatch,
//! op-amp saturation, switch injection, hold droop) are carried as a
//! separate additive error term that is exactly zero in
//! [`AnalogParams::ideal`] mode, so ideal-mode equivalence with the
//! reference is structural, not accidental.
//!
//! Rounds: when the layer was mapped in R > 1 rounds (more neurons than
//! M·N capacitors), the controller replays the step's events once per
//! round with the round's MEM image — the paper's capacitor reassignment.
//! Cycle and energy accounting include the replay cost.
//!
//! # One engine, every path
//!
//! This type is a thin shell around the unified lane-major engine in
//! [`crate::engine`]: it owns the distilled image, the CSR mirror, the
//! A-SYN bank and two [`engine::SoaState`]s — a stride-1 state for
//! sequential execution and a stride-B state for lane batches — and
//! forwards every step to [`engine::step`]. The perf semantics the engine
//! preserves (activity-tracked sweep, duplicate-event coalescing with
//! ×multiplicity accounting, one shared CSR walk per distinct event
//! across lanes, canonical ascending dispatch order, the Kahan error
//! sidecar that lets non-ideal analog mode batch too) are documented in
//! [`crate::engine`]; the differential suites
//! (`tests/lanes_differential.rs`, `tests/dirty_slot_invariant.rs`) pin
//! them against the L=1 instantiation and the oracle knobs.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::analog::{ASyn, AnalogParams};
use crate::config::AcceleratorConfig;
use crate::engine::{self, ConvGen, CoreView, LaneCtl, SoaState, StepScratch};
use crate::fault::{CoreFaults, FaultPlan};
use crate::mapping::CoreImage;
use crate::snn::LifParams;
use crate::util::rng::Rng;

/// Bound on the per-step statistic series in [`CoreStats`]
/// (`cycles_per_step`, `sn_rows_touched_per_step`). The series exist for
/// figure generation over short runs; a long-lived coordinator service
/// processes an unbounded request stream, and without a cap each lane's
/// series would grow by `2·T` entries per request forever. Recording
/// simply stops at the cap (every execution path applies it identically,
/// so lane/sequential bit-identity is unaffected); the scalar totals keep
/// accumulating.
pub const STEP_SERIES_CAP: usize = 1 << 20;

/// Per-step and cumulative statistics of one core (feeds the energy model
/// and Figures 6–7).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CoreStats {
    /// Clock cycles consumed, cumulative.
    pub cycles: u64,
    /// Events popped from MEM_E (per-round replays counted once per round).
    pub events_dispatched: u64,
    /// MEM_S&N rows streamed.
    pub sn_rows_read: u64,
    /// Synaptic MACs performed (A-SYN operations).
    pub macs: u64,
    /// A-NEURON integrate operations (one per deposited packet).
    pub integrations: u64,
    /// A-NEURON sweep (restore/compare/store or leak) operations.
    pub fire_ops: u64,
    /// Output spikes emitted.
    pub spikes_out: u64,
    /// MEM_E occupancy high-water mark.
    pub peak_event_queue: usize,
    /// MEM_E overflow drops (backpressure failure).
    pub dropped_events: u64,
    /// Per-time-step MEM_S&N rows *touched* (utilization series for
    /// Figures 6–7).
    pub sn_rows_touched_per_step: Vec<u64>,
    /// Per-time-step cycle counts.
    pub cycles_per_step: Vec<u64>,
    /// Injected-fault accounting ([`crate::fault::FaultPlan`]; all three
    /// stay 0 unless faults are installed, preserving `CoreStats`
    /// equality with fault-free runs): deposits suppressed because the
    /// entry's A-SYN engine (C2C ladder column) is stuck dead.
    pub stuck_row_hits: u64,
    /// Sweeps that discarded accumulated charge because the slot's op-amp
    /// is dead (membrane frozen, neuron never fires).
    pub dead_slot_hits: u64,
    /// Transient MEM_E single-bit flips injected at latch time.
    pub events_bit_flipped: u64,
}

/// Builds the engine's borrowed [`CoreView`] from a `NeuraCore`'s fields.
/// A macro instead of a method so the borrow checker sees disjoint
/// field-level borrows: the view takes the image-side fields immutably
/// while the caller passes the state/stats fields mutably in the same
/// expression.
macro_rules! core_view {
    ($core:expr) => {
        CoreView {
            image: &*$core.image,
            rows_index: &$core.rows_index,
            row_entries: &$core.row_entries,
            conv: $core.conv_gen.as_ref(),
            residents_sorted: &$core.residents_sorted,
            sweep_cost: &$core.sweep_cost,
            sweep_skip: $core.sweep_skip,
            lif: $core.lif,
            analog: &$core.analog,
            syns: &$core.syns,
            caps_per_engine: $core.caps_per_engine,
            faults: $core.faults.as_ref(),
            force_dense_sweep: $core.force_dense_sweep,
            force_per_event_dispatch: $core.force_per_event_dispatch,
            legacy_error_oracle: $core.force_legacy_error_oracle,
        }
    };
}

/// One MX-NEURACORE instance with loaded control memories.
#[derive(Debug, Clone)]
pub struct NeuraCore {
    /// Core index in the chain (= layer index).
    pub index: usize,
    /// Distilled control memories. `Arc`: images are immutable at run time
    /// and large (MEM_S&N rows + weight SRAM), so coordinator workers share
    /// one copy — chip cloning is O(state), not O(model).
    image: Arc<CoreImage>,
    /// Flattened `(slot = j·N+k, dst)` residents per round, **sorted by
    /// destination id** so the sweep emits spikes pre-sorted — iterated
    /// instead of the BTreeMap.
    residents_sorted: Vec<Vec<(u32, u32)>>,
    /// Per-round sweep cycle cost (max per-engine occupancy) — static,
    /// precomputed.
    sweep_cost: Vec<u64>,
    /// Whether the quiescent fixed point allows skipping clean slots in the
    /// sweep ([`engine::quiescent_fixed_point`]).
    sweep_skip: bool,
    /// Compact CSR mirror of each round's MEM_S&N: row `r` covers
    /// `row_entries[round][rows_index[round][r] .. rows_index[round][r+1]]`
    /// as `(engine, virt, weight)` — the dispatch loop skips empty engine
    /// columns entirely and reads the weight inline (the silicon's weight-
    /// SRAM read is still priced via the MAC count).
    rows_index: Vec<Vec<u32>>,
    row_entries: Vec<Vec<(u8, u16, i8)>>,
    /// Generator-based row fetch for compressed conv images (`Some` iff the
    /// image carries a [`crate::snn::ConvSpec`]): the CSR mirror above is
    /// empty and the dispatcher enumerates rows from the kernel instead.
    conv_gen: Option<ConvGen>,
    lif: LifParams,
    analog: AnalogParams,
    /// A-SYN engines (one per A-NEURON column, paper Figure 1); provide
    /// C2C mismatch modeling and MAC energy accounting.
    syns: Vec<ASyn>,
    /// Sequential execution state: the engine's literal L=1 instantiation
    /// (stride-1 lane-major state; see [`crate::engine`]).
    seq_state: SoaState,
    /// Sequential MEM_E queue + run scratch (lane 0's controller state).
    seq_ctl: LaneCtl,
    /// Lane-batch state: stride-B lane-major state, grown on demand by
    /// [`Self::ensure_lanes`]. Entirely disjoint from the sequential
    /// state, so interleaved `run`/`run_lanes` usage cannot cross-talk.
    lane_state: SoaState,
    /// Per-lane MEM_E queues + run scratch.
    lane_ctl: Vec<LaneCtl>,
    /// Per-lane statistics, attributed exactly as the sequential engine
    /// attributes [`Self::stats`] (same code path).
    lane_stats: Vec<CoreStats>,
    event_mem_depth: usize,
    /// Capacitors per A-NEURON (N).
    caps_per_engine: usize,
    pub stats: CoreStats,
    /// Scratch per-engine MAC counter, filled by the engine and flushed to
    /// the A-SYN energy accounts once per step (keeps the dispatch inner
    /// loop free of bookkeeping float adds).
    mac_count: Vec<u64>,
    /// Reusable engine step scratch (merge heap, cursors, accumulators).
    scratch: StepScratch,
    /// Test/debug knob: do full sweep arithmetic for every resident slot,
    /// ignoring the dirty flags (the pre-perf-pass behaviour). Used by the
    /// differential regression tests; keep `false` in production.
    pub force_dense_sweep: bool,
    /// Test/debug knob: dispatch each MEM_E entry individually instead of
    /// coalescing duplicates. Used by the differential regression tests.
    pub force_per_event_dispatch: bool,
    /// Test/debug knob: the **fixed-order oracle** — per-event dispatch in
    /// canonical ascending order with plain (uncompensated) error
    /// accumulation, i.e. the pre-refactor sequential engine's exact
    /// non-ideal arithmetic for inputs that arrive sorted and
    /// duplicate-free. The non-ideal differential tests pin the default
    /// engine to this oracle within
    /// [`engine::NONIDEAL_ORACLE_TOLERANCE`]. No effect in ideal mode
    /// beyond forcing per-event dispatch.
    pub force_legacy_error_oracle: bool,
    /// Realized hardware faults ([`FaultPlan::core_faults`]); `None` (the
    /// default) keeps every hot loop on the identical fault-free code
    /// path, so bit-identity with pre-fault builds is structural.
    faults: Option<CoreFaults>,
    /// Scratch for bit-flip corruption of incoming event batches.
    fault_scratch: Vec<u32>,
}

impl NeuraCore {
    /// Build a core from a distilled image. `analog` selects ideal vs
    /// paper-calibrated non-ideal circuit behaviour; `rng` seeds per-engine
    /// C2C mismatch when non-ideal.
    pub fn new(
        index: usize,
        image: CoreImage,
        lif: LifParams,
        analog: &AnalogParams,
        cfg: &AcceleratorConfig,
        rng: &mut Rng,
    ) -> Result<Self> {
        if image.num_engines != cfg.a_neurons_per_core {
            bail!(
                "image distilled for {} engines, core has {}",
                image.num_engines,
                cfg.a_neurons_per_core
            );
        }
        let m = cfg.a_neurons_per_core;
        let n = cfg.virtual_per_a_neuron;
        let syns = (0..m)
            .map(|j| {
                let mut fork = rng.fork((index * 1024 + j) as u64);
                ASyn::new(cfg.weight_bits, analog, Some(&mut fork))
            })
            .collect();
        let sweep_skip = engine::quiescent_fixed_point(&lif, analog);
        let residents_sorted: Vec<Vec<(u32, u32)>> = image
            .rounds
            .iter()
            .map(|r| {
                let mut v: Vec<(u32, u32)> = r
                    .residents
                    .iter()
                    .map(|(&(j, k), &d)| ((j as usize * n + k as usize) as u32, d))
                    .collect();
                v.sort_unstable_by_key(|&(_, d)| d);
                v
            })
            .collect();
        let sweep_cost: Vec<u64> = image
            .rounds
            .iter()
            .map(|r| {
                let mut per_engine = vec![0u64; m];
                for (&(j, _), _) in r.residents.iter() {
                    per_engine[j as usize] += 1;
                }
                per_engine.into_iter().max().unwrap_or(0)
            })
            .collect();
        let mut rows_index = Vec::with_capacity(image.rounds.len());
        let mut row_entries = Vec::with_capacity(image.rounds.len());
        for round in &image.rounds {
            let mut idx = Vec::with_capacity(round.sn_rows.len() + 1);
            let mut entries = Vec::new();
            idx.push(0u32);
            for row in &round.sn_rows {
                for (j, e) in row.per_engine.iter().enumerate() {
                    if let Some(e) = e {
                        entries.push((j as u8, e.virt, image.weight_mem[e.weight_addr as usize]));
                    }
                }
                idx.push(entries.len() as u32);
            }
            rows_index.push(idx);
            row_entries.push(entries);
        }
        let conv_gen =
            image.conv.map(|spec| ConvGen::new(spec, image.weight_mem.clone(), m, n));
        let rounds = image.rounds.len();
        Ok(Self {
            index,
            image: Arc::new(image),
            residents_sorted,
            sweep_cost,
            sweep_skip,
            rows_index,
            row_entries,
            conv_gen,
            lif,
            analog: analog.clone(),
            syns,
            seq_state: SoaState::new(rounds, m * n, 1, lif.v_reset, sweep_skip),
            seq_ctl: LaneCtl::default(),
            lane_state: SoaState::new(rounds, m * n, 0, lif.v_reset, sweep_skip),
            lane_ctl: Vec::new(),
            lane_stats: Vec::new(),
            event_mem_depth: cfg.event_mem_depth,
            caps_per_engine: n,
            stats: CoreStats::default(),
            mac_count: vec![0u64; m],
            scratch: StepScratch::default(),
            force_dense_sweep: false,
            force_per_event_dispatch: false,
            force_legacy_error_oracle: false,
            faults: None,
            fault_scratch: Vec::new(),
        })
    }

    /// Install (or, with an empty plan, clear) this core's realized
    /// hardware faults. The defect pattern and transient-fault stream are
    /// a pure function of `(plan.seed, self.index)` — reinstalling the
    /// same plan replays the same faults. Fault counters in
    /// [`Self::stats`] keep accumulating across installs.
    pub fn install_faults(&mut self, plan: &FaultPlan) {
        self.faults = plan.core_faults(self.index, self.syns.len(), self.caps_per_engine);
    }

    /// Whether hardware faults are installed.
    pub fn has_faults(&self) -> bool {
        self.faults.is_some()
    }

    /// `(stuck_row_hits, dead_slot_hits, events_bit_flipped)` summed over
    /// the core stats and every lane's stats — the monotonic totals the
    /// coordinator delta-publishes to [`crate::fault::RecoveryStats`].
    pub fn fault_counters(&self) -> (u64, u64, u64) {
        let mut t = (
            self.stats.stuck_row_hits,
            self.stats.dead_slot_hits,
            self.stats.events_bit_flipped,
        );
        for l in &self.lane_stats {
            t.0 += l.stuck_row_hits;
            t.1 += l.dead_slot_hits;
            t.2 += l.events_bit_flipped;
        }
        t
    }

    /// Monotonic execution-profile counters summed over the core stats and
    /// every lane's stats (mirrors [`Self::fault_counters`]) — the sample
    /// the coordinator delta-publishes to [`crate::obs::ProfilePlane`].
    pub fn profile_sample(&self) -> crate::obs::CoreSample {
        let mut s = crate::obs::CoreSample {
            cycles: self.stats.cycles,
            events: self.stats.events_dispatched,
            sn_rows: self.stats.sn_rows_read,
            macs: self.stats.macs,
            integrations: self.stats.integrations,
            fire_ops: self.stats.fire_ops,
            spikes: self.stats.spikes_out,
        };
        for l in &self.lane_stats {
            s.cycles += l.cycles;
            s.events += l.events_dispatched;
            s.sn_rows += l.sn_rows_read;
            s.macs += l.macs;
            s.integrations += l.integrations;
            s.fire_ops += l.fire_ops;
            s.spikes += l.spikes_out;
        }
        s
    }

    /// Number of mapping rounds.
    pub fn rounds(&self) -> usize {
        self.image.rounds.len()
    }

    /// Output (destination-layer) dimensionality.
    pub fn out_dim(&self) -> usize {
        self.image.out_dim
    }

    /// Input (source-layer) dimensionality.
    pub fn in_dim(&self) -> usize {
        self.image.in_dim
    }

    /// Latch incoming events (source-neuron indices) into MEM_E. Returns
    /// the number of dropped events if the memory overflows.
    ///
    /// With an installed [`FaultPlan`] carrying `bit_flip_p > 0`, each
    /// event's source id may be corrupted by a transient single-bit flip
    /// *before* the latch — an out-of-range result addresses no MEM_E2A
    /// entry and is silently dropped by the dispatcher, exactly like a
    /// malformed input spike.
    pub fn push_events(&mut self, events: &[u32]) -> usize {
        let events: &[u32] = match self.faults.as_mut() {
            Some(f) if f.bit_flip_p > 0.0 => {
                corrupt_events(f, &mut self.fault_scratch, &mut self.stats, self.image.in_dim, events);
                &self.fault_scratch
            }
            _ => events,
        };
        engine::latch_events(&mut self.seq_ctl.queue, &mut self.stats, self.event_mem_depth, events)
    }

    /// Execute one global time step: dispatch all latched events through
    /// every round, sweep fire/leak, return the emitted spikes (destination
    /// layer neuron ids, sorted ascending).
    ///
    /// Allocates a fresh output vector; the hot path ([`crate::accel`])
    /// uses [`Self::step_into`] with a reused buffer instead.
    pub fn step(&mut self) -> Vec<u32> {
        let mut out = Vec::new();
        self.step_into(&mut out);
        out
    }

    /// [`Self::step`] writing the emitted spikes into a caller-owned buffer
    /// (cleared first) — allocation-free on the steady state.
    ///
    /// This is the unified engine's **L=1 instantiation**: the same
    /// [`engine::step`] the lane path runs, over the stride-1 sequential
    /// state, with the core's own [`Self::stats`] as lane 0's statistics.
    pub fn step_into(&mut self, out: &mut Vec<u32>) {
        let view = core_view!(self);
        engine::step(
            &view,
            &mut self.seq_state,
            std::slice::from_mut(&mut self.seq_ctl),
            std::slice::from_mut(&mut self.stats),
            &[0],
            std::slice::from_mut(out),
            &mut self.mac_count,
            &mut self.scratch,
        );
        self.flush_mac_energy();
    }

    /// Reset membrane state (between inputs) without clearing statistics.
    pub fn reset_membranes(&mut self) {
        self.seq_state.reset(self.lif.v_reset, self.sweep_skip);
        self.seq_ctl.queue.clear();
    }

    // -----------------------------------------------------------------
    // Lane execution (see `crate::engine` module docs)
    // -----------------------------------------------------------------

    /// Configure the core for at least `b` lanes. Lanes only ever *grow*:
    /// a smaller batch leaves the extra lanes (and, crucially, their
    /// accumulated [`CoreStats`] — which feed [`Self::analog_energy`] and
    /// the coordinator's shutdown accounting) in place; new lanes start
    /// quiescent. Lane identity is positional: lane `i` of a batch maps to
    /// the same lane-major column across repeated runs.
    pub fn ensure_lanes(&mut self, b: usize) {
        self.lane_state.grow_lanes(b, self.lif.v_reset, self.sweep_skip);
        while self.lane_ctl.len() < b {
            self.lane_ctl.push(LaneCtl::default());
        }
        while self.lane_stats.len() < b {
            self.lane_stats.push(CoreStats::default());
        }
    }

    /// Number of configured lanes.
    pub fn num_lanes(&self) -> usize {
        self.lane_state.lanes()
    }

    /// Reset every lane's membrane state (between batches) without
    /// clearing the per-lane statistics — the lane analogue of
    /// [`Self::reset_membranes`].
    pub fn reset_lanes(&mut self) {
        self.lane_state.reset(self.lif.v_reset, self.sweep_skip);
        for ctl in self.lane_ctl.iter_mut() {
            ctl.queue.clear();
        }
    }

    /// Reset **one** lane's membrane state and MEM_E queue without
    /// touching any other lane — the streaming-session primitive: opening
    /// a session on a recycled lane must not perturb the resident state of
    /// its neighbours. The lane's accumulated [`CoreStats`] are kept
    /// (fold them first with [`Self::fold_one_lane`] if the lane is being
    /// handed to a new owner).
    pub fn reset_lane(&mut self, lane: usize) {
        self.lane_state.reset_lane(lane, self.lif.v_reset, self.sweep_skip);
        self.lane_ctl[lane].queue.clear();
    }

    /// Fold **one** lane's accumulated scalar statistics into the
    /// core-level [`Self::stats`] and zero that lane's counters — the
    /// single-lane form of [`Self::fold_lane_stats`], used when a
    /// streaming session is evicted and its lane slot reused: without the
    /// fold, the departing session's work would be attributed to the next
    /// session or lost entirely at shutdown. Per-step series are dropped,
    /// exactly as in the all-lane fold.
    pub fn fold_one_lane(&mut self, lane: usize) {
        let s = std::mem::take(&mut self.lane_stats[lane]);
        fold_scalar_stats(&mut self.stats, s);
    }

    /// Per-lane statistics (bit-identical to a fresh sequential core fed
    /// the same input — sequential execution is the same engine at L=1).
    pub fn lane_stats(&self, lane: usize) -> &CoreStats {
        &self.lane_stats[lane]
    }

    /// Latch incoming events into lane `lane`'s MEM_E — the same latch
    /// policy as [`Self::push_events`] (one shared helper keeps the
    /// overflow semantics lockstep), against the lane's private queue and
    /// stats.
    pub fn push_events_lane(&mut self, lane: usize, events: &[u32]) -> usize {
        let events: &[u32] = match self.faults.as_mut() {
            Some(f) if f.bit_flip_p > 0.0 => {
                corrupt_events(
                    f,
                    &mut self.fault_scratch,
                    &mut self.lane_stats[lane],
                    self.image.in_dim,
                    events,
                );
                &self.fault_scratch
            }
            _ => events,
        };
        engine::latch_events(
            &mut self.lane_ctl[lane].queue,
            &mut self.lane_stats[lane],
            self.event_mem_depth,
            events,
        )
    }

    /// Execute one global time step for the lanes listed in `active`
    /// (strictly ascending lane indices), writing lane `active[i]`'s
    /// emitted spikes into `outs[i]` (cleared first).
    ///
    /// All active lanes share one CSR walk — in *every* analog mode: the
    /// merged ascending stream of distinct events is dispatched once per
    /// event, depositing into every carrying lane's contiguous SoA block.
    /// Per-lane outputs and [`CoreStats`] are bit-identical to sequential
    /// execution because sequential execution is this same engine at L=1
    /// (see [`crate::engine`]).
    pub fn step_lanes_into(&mut self, active: &[usize], outs: &mut [Vec<u32>]) {
        let view = core_view!(self);
        engine::step(
            &view,
            &mut self.lane_state,
            &mut self.lane_ctl,
            &mut self.lane_stats,
            active,
            outs,
            &mut self.mac_count,
            &mut self.scratch,
        );
        self.flush_mac_energy();
    }

    /// Flush the engine's batched per-engine MAC counts into the A-SYN
    /// energy accounts (core-level: MAC energy is attributed to the
    /// silicon, not to lanes).
    fn flush_mac_energy(&mut self) {
        for (syn, &cnt) in self.syns.iter_mut().zip(self.mac_count.iter()) {
            if cnt > 0 {
                syn.macs += cnt;
                syn.energy += cnt as f64 * syn.energy_per_mac;
            }
        }
        self.mac_count.fill(0);
    }

    /// Fold every lane's accumulated *scalar* statistics into the
    /// core-level [`Self::stats`] and reset the lanes' own counters.
    /// Downstream consumers — the energy report, the CLI's merged
    /// shutdown chips — read only `stats`, so without this a lane-served
    /// workload would be invisible to them. Per-lane attribution is
    /// collapsed; call it at the end of a chip's service life (the
    /// coordinator's workers fold before handing their chips back).
    /// [`Self::analog_energy`] is unchanged by folding (it already sums
    /// both).
    ///
    /// The per-step series (`cycles_per_step`, `sn_rows_touched_per_step`)
    /// are **dropped**, not concatenated: each lane's series is its own
    /// timeline, and splicing them onto the core's would fabricate a
    /// step-by-step history that never happened (and break the figure
    /// consumers the series exist for). Capture [`Self::lane_stats`]
    /// before folding if per-lane series are needed.
    pub fn fold_lane_stats(&mut self) {
        let stats = &mut self.stats;
        for lane in self.lane_stats.iter_mut() {
            fold_scalar_stats(stats, std::mem::take(lane));
        }
    }

    /// Debug/test introspection: `(mem, acc, dirty)` per slot of one round
    /// of the *sequential* state (the dirty-slot invariant property tests).
    pub fn slot_states(&self, round: usize) -> Vec<(f32, i32, bool)> {
        self.seq_state.slot_states(round, 0)
    }

    /// Debug/test introspection: `(mem, acc, dirty)` per slot of one round
    /// of lane `lane`'s state.
    pub fn lane_slot_states(&self, lane: usize, round: usize) -> Vec<(f32, i32, bool)> {
        self.lane_state.slot_states(round, lane)
    }

    /// Whether the quiescent-fixed-point sweep skip is enabled
    /// ([`engine::quiescent_fixed_point`]).
    pub fn sweep_skip_enabled(&self) -> bool {
        self.sweep_skip
    }

    /// Total analog energy consumed so far (J): A-SYN MACs plus A-NEURON
    /// integrate and sweep operations at the paper's per-op energy. Lane
    /// executions contribute through both terms (MAC energy accumulates in
    /// the shared A-SYN accounts; neuron ops live in the per-lane stats).
    pub fn analog_energy(&self) -> f64 {
        let mac_energy: f64 = self.syns.iter().map(|s| s.energy).sum();
        let mut neuron_ops = self.stats.integrations + self.stats.fire_ops;
        for lane in &self.lane_stats {
            neuron_ops += lane.integrations + lane.fire_ops;
        }
        mac_energy + neuron_ops as f64 * self.analog.neuron_energy_per_op
    }

    /// MEM_S&N rows present in the image, across rounds.
    pub fn image_sn_rows(&self) -> usize {
        self.image.rounds.iter().map(|r| r.sn_rows.len()).sum()
    }

    /// Weight SRAM bytes used.
    pub fn weight_bytes(&self) -> usize {
        self.image.weight_mem.len()
    }

    /// A-SYN MAC energy constant (J) — exposed for the energy model.
    pub fn mac_energy(&self) -> f64 {
        self.syns[0].energy_per_mac
    }
}

/// Fold one lane's scalar counters into a core-level [`CoreStats`] — the
/// single definition both [`NeuraCore::fold_lane_stats`] and
/// [`NeuraCore::fold_one_lane`] share, so all-lane and per-lane folding
/// cannot diverge. Per-step series are intentionally not concatenated
/// (see [`NeuraCore::fold_lane_stats`]).
fn fold_scalar_stats(into: &mut CoreStats, s: CoreStats) {
    into.cycles += s.cycles;
    into.events_dispatched += s.events_dispatched;
    into.sn_rows_read += s.sn_rows_read;
    into.macs += s.macs;
    into.integrations += s.integrations;
    into.fire_ops += s.fire_ops;
    into.spikes_out += s.spikes_out;
    into.peak_event_queue = into.peak_event_queue.max(s.peak_event_queue);
    into.dropped_events += s.dropped_events;
    into.stuck_row_hits += s.stuck_row_hits;
    into.dead_slot_hits += s.dead_slot_hits;
    into.events_bit_flipped += s.events_bit_flipped;
}

/// Apply the transient MEM_E bit-flip fault to one incoming event batch:
/// each event is independently corrupted with probability `bit_flip_p` by
/// flipping one uniformly chosen bit among the bits that address `in_dim`
/// sources. The corrupted batch lands in `scratch` (reused allocation);
/// flips are counted in `stats.events_bit_flipped`. A free function taking
/// the core's fields separately so the borrow checker sees the disjoint
/// field borrows.
fn corrupt_events(
    f: &mut CoreFaults,
    scratch: &mut Vec<u32>,
    stats: &mut CoreStats,
    in_dim: usize,
    events: &[u32],
) {
    let bits = (usize::BITS - in_dim.saturating_sub(1).leading_zeros()).max(1) as usize;
    scratch.clear();
    scratch.extend_from_slice(events);
    for e in scratch.iter_mut() {
        if f.rng.bernoulli(f.bit_flip_p) {
            *e ^= 1 << f.rng.below(bits);
            stats.events_bit_flipped += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::engine::{quiescent_fixed_point, NONIDEAL_ORACLE_TOLERANCE};
    use crate::mapping::{distill, map_layer, Strategy};
    use crate::snn::{reference_forward, LifParams, QuantLayer, QuantNetwork, SpikeTrain};
    use crate::util::rng::Rng;

    fn small_cfg(m: usize, n: usize) -> AcceleratorConfig {
        let mut c = AcceleratorConfig::accel1();
        c.a_neurons_per_core = m;
        c.a_syns_per_core = m;
        c.virtual_per_a_neuron = n;
        c
    }

    fn build_core(layer: &QuantLayer, cfg: &AcceleratorConfig, ideal: bool) -> NeuraCore {
        let mp = map_layer(layer, cfg, Strategy::IlpFlow).unwrap();
        mp.validate(layer, cfg).unwrap();
        let img = distill(layer, &mp, cfg).unwrap();
        let analog = if ideal { AnalogParams::ideal() } else { AnalogParams::paper() };
        let mut rng = Rng::new(99);
        NeuraCore::new(0, img, layer.lif, &analog, cfg, &mut rng).unwrap()
    }

    fn run_core(core: &mut NeuraCore, input: &SpikeTrain) -> SpikeTrain {
        let mut out = SpikeTrain::new(core.out_dim(), input.timesteps());
        for t in 0..input.timesteps() {
            core.push_events(&input.spikes[t]);
            out.spikes[t] = core.step();
        }
        out
    }

    fn random_layer(in_dim: usize, out_dim: usize, sparsity: f64, seed: u64) -> QuantLayer {
        let mut rng = Rng::new(seed);
        let mut w = vec![0i8; in_dim * out_dim];
        for x in w.iter_mut() {
            if !rng.bernoulli(sparsity) {
                *x = rng.range_inclusive(-127, 127) as i8;
            }
        }
        QuantLayer::new(
            in_dim,
            out_dim,
            w,
            0.02,
            LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 },
        )
        .unwrap()
    }

    fn random_input(dim: usize, t: usize, rate: f64, seed: u64) -> SpikeTrain {
        let mut rng = Rng::new(seed);
        let mut st = SpikeTrain::new(dim, t);
        for step in st.spikes.iter_mut() {
            for i in 0..dim {
                if rng.bernoulli(rate) {
                    step.push(i as u32);
                }
            }
        }
        st
    }

    /// The core in ideal-analog mode must match the reference bit-exactly.
    #[test]
    fn core_matches_reference_single_round() {
        let layer = random_layer(30, 12, 0.4, 1);
        let cfg = small_cfg(4, 4); // capacity 16 ≥ 12: single round
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 12 };
        let input = random_input(30, 12, 0.15, 2);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "ideal core ≠ reference");
        assert!(core.stats.macs > 0);
        assert!(core.stats.cycles > 0);
    }

    /// Multi-round mapping (more neurons than capacitors) must also match.
    #[test]
    fn core_matches_reference_multi_round() {
        let layer = random_layer(20, 30, 0.5, 3);
        let cfg = small_cfg(3, 4); // capacity 12 < 30: ≥3 rounds
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 10 };
        let input = random_input(20, 10, 0.2, 4);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        assert!(core.rounds() >= 3);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "multi-round ≠ reference");
    }

    /// Property: ideal equivalence holds across many random instances.
    #[test]
    fn prop_ideal_equivalence() {
        crate::util::prop::check_n("core-ref-equivalence", 20, |rng| {
            let in_dim = 5 + rng.below(30);
            let out_dim = 3 + rng.below(25);
            let m = 2 + rng.below(4);
            let n = 1 + rng.below(5);
            let layer = random_layer(in_dim, out_dim, 0.3 + rng.f64() * 0.5, rng.next_u64());
            let cfg = small_cfg(m, n);
            let t = 4 + rng.below(8);
            let input = random_input(in_dim, t, 0.1 + rng.f64() * 0.3, rng.next_u64());
            let net = QuantNetwork { name: "p".into(), layers: vec![layer.clone()], timesteps: t };
            let golden = reference_forward(&net, &input).map_err(|e| e.to_string())?;
            let mut core = build_core(&layer, &cfg, true);
            let out = run_core(&mut core, &input);
            if out.spikes != golden.output().spikes {
                return Err(format!(
                    "divergence: m={m} n={n} in={in_dim} out={out_dim} t={t}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn mismatch_only_mode_close_to_reference() {
        // C2C mismatch alone (no rail clamp, no injection, no droop) must
        // perturb spike counts by only a few percent.
        let layer = random_layer(40, 16, 0.4, 5);
        let cfg = small_cfg(4, 4);
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 20 };
        let input = random_input(40, 20, 0.15, 6);
        let golden = reference_forward(&net, &input).unwrap();
        let mut analog = AnalogParams::ideal();
        analog.c2c_mismatch_sigma = 0.002;
        let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        let img = distill(&layer, &mp, &cfg).unwrap();
        let mut rng = Rng::new(99);
        let mut core = NeuraCore::new(0, img, layer.lif, &analog, &cfg, &mut rng).unwrap();
        let out = run_core(&mut core, &input);
        let g = golden.output().total_spikes() as f64;
        let o = out.total_spikes() as f64;
        assert!(
            (o - g).abs() <= (0.10 * g).max(2.0),
            "mismatch-only spikes {o} too far from golden {g}"
        );
    }

    #[test]
    fn paper_analog_mode_same_order_as_reference() {
        // Full non-ideal mode adds the supply-rail clamp, which the
        // rail-less reference cannot reproduce: membranes that would drift
        // deeply negative recover sooner, so the count shifts — but must
        // stay within the same order (factor ~2) and the core must still
        // be live.
        let layer = random_layer(40, 16, 0.4, 5);
        let cfg = small_cfg(4, 4);
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 20 };
        let input = random_input(40, 20, 0.15, 6);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, false);
        let out = run_core(&mut core, &input);
        let g = golden.output().total_spikes() as f64;
        let o = out.total_spikes() as f64;
        assert!(o > 0.0);
        assert!(o <= 2.5 * g && o >= g / 2.5, "non-ideal spikes {o} vs golden {g}");
    }

    #[test]
    fn cycles_scale_with_activity() {
        let layer = random_layer(30, 10, 0.3, 7);
        let cfg = small_cfg(5, 2);
        let quiet = random_input(30, 10, 0.02, 8);
        let busy = random_input(30, 10, 0.5, 9);
        let mut c1 = build_core(&layer, &cfg, true);
        run_core(&mut c1, &quiet);
        let mut c2 = build_core(&layer, &cfg, true);
        run_core(&mut c2, &busy);
        assert!(
            c2.stats.cycles > c1.stats.cycles,
            "busy {} ≤ quiet {}",
            c2.stats.cycles,
            c1.stats.cycles
        );
        assert!(c2.stats.sn_rows_read > c1.stats.sn_rows_read);
    }

    #[test]
    fn event_memory_overflow_drops() {
        let layer = random_layer(100, 4, 0.5, 10);
        let mut cfg = small_cfg(2, 2);
        cfg.event_mem_depth = 8;
        let mut core = build_core(&layer, &cfg, true);
        let events: Vec<u32> = (0..20).collect();
        let dropped = core.push_events(&events);
        assert_eq!(dropped, 12);
        assert_eq!(core.stats.dropped_events, 12);
        assert_eq!(core.stats.peak_event_queue, 8);
    }

    #[test]
    fn reset_membranes_clears_state_keeps_stats() {
        let layer = random_layer(20, 8, 0.3, 11);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, true);
        let input = random_input(20, 6, 0.3, 12);
        run_core(&mut core, &input);
        let cycles = core.stats.cycles;
        assert!(cycles > 0);
        core.reset_membranes();
        assert_eq!(core.stats.cycles, cycles, "stats must survive reset");
        // State is cleared: a silent step emits nothing.
        let out = core.step();
        assert!(out.is_empty());
    }

    #[test]
    fn per_step_series_lengths_match() {
        let layer = random_layer(20, 8, 0.3, 13);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, true);
        let input = random_input(20, 7, 0.2, 14);
        run_core(&mut core, &input);
        // 7 event steps + 1 silent step from reset test? No: exactly 7.
        assert_eq!(core.stats.cycles_per_step.len(), 7);
        assert_eq!(core.stats.sn_rows_touched_per_step.len(), 7);
        assert_eq!(
            core.stats.cycles_per_step.iter().sum::<u64>(),
            core.stats.cycles
        );
    }

    #[test]
    fn analog_energy_accumulates() {
        let layer = random_layer(20, 8, 0.3, 15);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, false);
        assert_eq!(core.analog_energy(), 0.0);
        let input = random_input(20, 5, 0.3, 16);
        run_core(&mut core, &input);
        assert!(core.analog_energy() > 0.0);
        let expected = (core.stats.integrations + core.stats.fire_ops) as f64
            * AnalogParams::paper().neuron_energy_per_op
            + core.stats.macs as f64 * core.mac_energy();
        assert!((core.analog_energy() - expected).abs() / expected < 1e-9);
    }

    /// Differential regression: the activity-tracked sweep and event
    /// coalescing must leave every [`CoreStats`] counter AND the output
    /// spikes bit-identical to the dense/per-event execution path
    /// (`force_dense_sweep` / `force_per_event_dispatch` replicate the
    /// pre-perf-pass behaviour).
    #[test]
    fn sparse_execution_stats_match_dense_execution() {
        for (seed, m, n) in [(21u64, 4usize, 4usize), (22, 3, 5), (23, 5, 2)] {
            let layer = random_layer(40, 24, 0.4, seed);
            let cfg = small_cfg(m, n);
            let input = random_input(40, 15, 0.12, seed + 100);

            let mut fast = build_core(&layer, &cfg, true);
            let out_fast = run_core(&mut fast, &input);

            let mut dense = build_core(&layer, &cfg, true);
            dense.force_dense_sweep = true;
            dense.force_per_event_dispatch = true;
            let out_dense = run_core(&mut dense, &input);

            assert_eq!(out_fast.spikes, out_dense.spikes, "seed {seed}: outputs diverge");
            let (f, d) = (&fast.stats, &dense.stats);
            assert_eq!(f.cycles, d.cycles, "seed {seed}: cycles");
            assert_eq!(f.fire_ops, d.fire_ops, "seed {seed}: fire_ops");
            assert_eq!(f.macs, d.macs, "seed {seed}: macs");
            assert_eq!(f.sn_rows_read, d.sn_rows_read, "seed {seed}: sn_rows_read");
            assert_eq!(f.events_dispatched, d.events_dispatched, "seed {seed}");
            assert_eq!(f.integrations, d.integrations, "seed {seed}");
            assert_eq!(f.spikes_out, d.spikes_out, "seed {seed}");
            assert_eq!(f.cycles_per_step, d.cycles_per_step, "seed {seed}");
            assert_eq!(
                f.sn_rows_touched_per_step, d.sn_rows_touched_per_step,
                "seed {seed}"
            );
            assert!(
                (fast.analog_energy() - dense.analog_energy()).abs() <= f64::EPSILON,
                "seed {seed}: energy accounting diverges"
            );
        }
    }

    /// Duplicate MEM_E entries (same source spiking "twice" in a step, as a
    /// caller may inject) must behave identically coalesced or not —
    /// including the ×multiplicity cycle/row/MAC accounting.
    #[test]
    fn coalesced_duplicates_match_per_event_dispatch() {
        let layer = random_layer(20, 12, 0.3, 31);
        let cfg = small_cfg(4, 3);
        // Deliberately unsorted with duplicates: exercises the sort +
        // run-length path.
        let events: Vec<u32> = vec![5, 1, 5, 5, 2, 1, 9, 9];

        let mut fast = build_core(&layer, &cfg, true);
        let mut dense = build_core(&layer, &cfg, true);
        dense.force_per_event_dispatch = true;

        for _ in 0..4 {
            fast.push_events(&events);
            dense.push_events(&events);
            assert_eq!(fast.step(), dense.step(), "outputs diverge");
        }
        assert_eq!(fast.stats.cycles, dense.stats.cycles);
        assert_eq!(fast.stats.events_dispatched, dense.stats.events_dispatched);
        assert_eq!(fast.stats.sn_rows_read, dense.stats.sn_rows_read);
        assert_eq!(fast.stats.macs, dense.stats.macs);
        assert_eq!(fast.stats.integrations, dense.stats.integrations);
        assert_eq!(fast.stats.events_dispatched as usize, 8 * 4 * fast.rounds());
    }

    /// A non-zero `v_reset` whose leak is not a fixed point must disable
    /// sweep skipping (every slot permanently dirty) and still match the
    /// reference bit-exactly.
    #[test]
    fn nonzero_v_reset_disables_skip_and_matches_reference() {
        let lif = LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.25 };
        assert!(!quiescent_fixed_point(&lif, &AnalogParams::ideal()));
        let mut rng = Rng::new(41);
        let mut w = vec![0i8; 30 * 12];
        for x in w.iter_mut() {
            if !rng.bernoulli(0.4) {
                *x = rng.range_inclusive(-127, 127) as i8;
            }
        }
        let layer = QuantLayer::new(30, 12, w, 0.02, lif).unwrap();
        let cfg = small_cfg(4, 4);
        let net =
            QuantNetwork { name: "vr".into(), layers: vec![layer.clone()], timesteps: 12 };
        let input = random_input(30, 12, 0.15, 42);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "v_reset≠0 core ≠ reference");
    }

    /// `beta == 1, v_reset == 0` IS a fixed point (no leak decay) — the
    /// skip stays valid.
    #[test]
    fn quiescence_check_accepts_no_leak() {
        let lif = LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 0.0 };
        assert!(quiescent_fixed_point(&lif, &AnalogParams::ideal()));
        // A reset value at/above threshold would fire forever: not quiescent.
        let hot = LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 1.0 };
        assert!(!quiescent_fixed_point(&hot, &AnalogParams::ideal()));
    }

    /// step_into reuses the caller's buffer and matches step().
    #[test]
    fn step_into_matches_step() {
        let layer = random_layer(20, 8, 0.3, 51);
        let cfg = small_cfg(2, 4);
        let input = random_input(20, 6, 0.3, 52);
        let mut a = build_core(&layer, &cfg, true);
        let mut b = build_core(&layer, &cfg, true);
        let mut buf = vec![99u32; 7]; // stale contents must be cleared
        for t in 0..input.timesteps() {
            a.push_events(&input.spikes[t]);
            b.push_events(&input.spikes[t]);
            b.step_into(&mut buf);
            assert_eq!(a.step(), buf, "step {t}");
        }
        assert_eq!(a.stats.cycles, b.stats.cycles);
    }

    /// Drive a batch through the lane API at core level: one push + step
    /// per global time step, lanes shorter than the longest input going
    /// inactive once exhausted.
    fn run_core_lanes(core: &mut NeuraCore, inputs: &[SpikeTrain]) -> Vec<SpikeTrain> {
        let b = inputs.len();
        core.ensure_lanes(b);
        core.reset_lanes();
        let t_max = inputs.iter().map(|s| s.timesteps()).max().unwrap_or(0);
        let mut outs: Vec<SpikeTrain> = inputs
            .iter()
            .map(|s| SpikeTrain::new(core.out_dim(), s.timesteps()))
            .collect();
        let mut bufs: Vec<Vec<u32>> = Vec::new();
        for t in 0..t_max {
            let active: Vec<usize> =
                (0..b).filter(|&i| t < inputs[i].timesteps()).collect();
            bufs.resize_with(active.len(), Vec::new);
            for &i in &active {
                core.push_events_lane(i, &inputs[i].spikes[t]);
            }
            core.step_lanes_into(&active, &mut bufs);
            for (ai, &i) in active.iter().enumerate() {
                outs[i].spikes[t] = std::mem::take(&mut bufs[ai]);
            }
        }
        outs
    }

    /// The shared-CSR lane walk must be bit-identical — outputs AND every
    /// per-lane CoreStats counter — to fresh sequential cores.
    #[test]
    fn lanes_match_sequential_per_core() {
        let layer = random_layer(30, 18, 0.4, 61);
        let cfg = small_cfg(3, 4); // capacity 12 < 18: multi-round
        let inputs: Vec<SpikeTrain> = (0..4)
            .map(|i| random_input(30, 10, 0.05 + 0.1 * i as f64, 70 + i as u64))
            .collect();

        let mut laned = build_core(&layer, &cfg, true);
        let lane_outs = run_core_lanes(&mut laned, &inputs);

        for (i, input) in inputs.iter().enumerate() {
            let mut seq = build_core(&layer, &cfg, true);
            let seq_out = run_core(&mut seq, input);
            assert_eq!(lane_outs[i].spikes, seq_out.spikes, "lane {i}: outputs");
            assert_eq!(laned.lane_stats(i), &seq.stats, "lane {i}: stats");
        }
        // Core-level sequential stats stay untouched by lane execution.
        assert_eq!(laned.stats, CoreStats::default());
    }

    /// Duplicate events in a lane's queue take the coalesced path; the
    /// ×multiplicity accounting must match per-event dispatch.
    #[test]
    fn lane_duplicates_match_force_per_event() {
        let layer = random_layer(20, 12, 0.3, 62);
        let cfg = small_cfg(4, 3);
        let events: Vec<u32> = vec![5, 1, 5, 5, 2, 1, 9, 9];
        let mut input = SpikeTrain::new(20, 4);
        for t in 0..4 {
            input.spikes[t] = events.clone();
        }
        let inputs = vec![input.clone(), input];

        let mut fast = build_core(&layer, &cfg, true);
        let fast_outs = run_core_lanes(&mut fast, &inputs);
        let mut slow = build_core(&layer, &cfg, true);
        slow.force_per_event_dispatch = true;
        let slow_outs = run_core_lanes(&mut slow, &inputs);

        for i in 0..2 {
            assert_eq!(fast_outs[i].spikes, slow_outs[i].spikes, "lane {i}");
            assert_eq!(fast.lane_stats(i), slow.lane_stats(i), "lane {i}: stats");
        }
    }

    /// Non-ideal analog mode shares the lane walk too (the Kahan error
    /// sidecar is order-robust and deposits happen in canonical order) —
    /// bit-identical to per-lane sequential cores (same mismatch seeds),
    /// because the sequential engine is the same code at L=1.
    #[test]
    fn nonideal_lanes_share_walk_and_match_sequential() {
        let layer = random_layer(25, 10, 0.4, 63);
        let cfg = small_cfg(5, 2);
        let inputs: Vec<SpikeTrain> =
            (0..3).map(|i| random_input(25, 8, 0.2, 80 + i as u64)).collect();

        let mut laned = build_core(&layer, &cfg, false);
        let lane_outs = run_core_lanes(&mut laned, &inputs);
        for (i, input) in inputs.iter().enumerate() {
            let mut seq = build_core(&layer, &cfg, false);
            let seq_out = run_core(&mut seq, input);
            assert_eq!(lane_outs[i].spikes, seq_out.spikes, "lane {i}: outputs");
            assert_eq!(laned.lane_stats(i), &seq.stats, "lane {i}: stats");
        }
    }

    /// The documented non-ideal tolerance contract: the default engine
    /// (coalesced dispatch, Kahan error sidecar) against the fixed-order
    /// per-event oracle (`force_legacy_error_oracle` — the pre-refactor
    /// arithmetic for sorted inputs). Inputs deliberately contain
    /// duplicates so the ×multiplicity error fold is exercised; every
    /// membrane must stay within `NONIDEAL_ORACLE_TOLERANCE` per step and
    /// the spike trains must agree for these fixed seeds.
    #[test]
    fn nonideal_kahan_engine_within_tolerance_of_fixed_order_oracle() {
        let layer = random_layer(30, 14, 0.4, 66);
        let cfg = small_cfg(4, 4);
        let mut input = random_input(30, 10, 0.2, 67);
        input.duplicate_events(); // exercises the ×mult error fold

        let mut fast = build_core(&layer, &cfg, false);
        let mut oracle = build_core(&layer, &cfg, false);
        oracle.force_legacy_error_oracle = true;

        for t in 0..input.timesteps() {
            fast.push_events(&input.spikes[t]);
            oracle.push_events(&input.spikes[t]);
            let a = fast.step();
            let b = oracle.step();
            assert_eq!(a, b, "step {t}: spike outputs diverge beyond tolerance");
            for round in 0..fast.rounds() {
                for (slot, (f, o)) in fast
                    .slot_states(round)
                    .iter()
                    .zip(oracle.slot_states(round).iter())
                    .enumerate()
                {
                    assert!(
                        (f.0 - o.0).abs() <= NONIDEAL_ORACLE_TOLERANCE,
                        "step {t} round {round} slot {slot}: mem {} vs oracle {}",
                        f.0,
                        o.0
                    );
                    assert_eq!(f.1, o.1, "integer charge must be exact");
                }
            }
        }
        // The accounting is unaffected by the error representation:
        // per-event oracle and coalesced dispatch charge identical
        // ×multiplicity costs.
        assert_eq!(fast.stats.cycles, oracle.stats.cycles);
        assert_eq!(fast.stats.events_dispatched, oracle.stats.events_dispatched);
        assert_eq!(fast.stats.macs, oracle.stats.macs);
    }

    /// ensure_lanes keeps existing lane state, reset_lanes clears state but
    /// keeps stats, and lane overflow accounting is per-lane.
    #[test]
    fn lane_lifecycle_and_overflow() {
        let layer = random_layer(40, 8, 0.4, 64);
        let mut cfg = small_cfg(2, 4);
        cfg.event_mem_depth = 8;
        let mut core = build_core(&layer, &cfg, true);
        core.ensure_lanes(2);
        assert_eq!(core.num_lanes(), 2);
        let events: Vec<u32> = (0..20).collect();
        let dropped = core.push_events_lane(1, &events);
        assert_eq!(dropped, 12);
        assert_eq!(core.lane_stats(1).dropped_events, 12);
        assert_eq!(core.lane_stats(1).peak_event_queue, 8);
        assert_eq!(core.lane_stats(0).dropped_events, 0);
        let cycles_before = {
            let mut bufs = vec![Vec::new(), Vec::new()];
            core.step_lanes_into(&[0, 1], &mut bufs);
            core.lane_stats(1).cycles
        };
        assert!(cycles_before > 0);
        core.reset_lanes();
        assert_eq!(core.lane_stats(1).cycles, cycles_before, "stats survive reset");
        // Growing keeps old lanes, adds quiescent ones.
        core.ensure_lanes(3);
        assert_eq!(core.num_lanes(), 3);
        assert_eq!(core.lane_stats(1).cycles, cycles_before);
        assert_eq!(core.lane_stats(2).cycles, 0);
    }

    /// fold_lane_stats moves every counter into core stats, zeroes the
    /// lanes, and leaves the energy total bit-identical.
    #[test]
    fn fold_lane_stats_moves_totals_to_core() {
        let layer = random_layer(30, 12, 0.4, 65);
        let cfg = small_cfg(4, 3);
        let inputs: Vec<SpikeTrain> =
            (0..3).map(|i| random_input(30, 6, 0.2, 90 + i as u64)).collect();
        let mut core = build_core(&layer, &cfg, true);
        run_core_lanes(&mut core, &inputs);
        let energy_before = core.analog_energy();
        let expected_macs: u64 = (0..3).map(|i| core.lane_stats(i).macs).sum();
        let expected_cycles: u64 = (0..3).map(|i| core.lane_stats(i).cycles).sum();
        assert!(expected_macs > 0);
        core.fold_lane_stats();
        assert_eq!(core.stats.macs, expected_macs);
        assert_eq!(core.stats.cycles, expected_cycles);
        for i in 0..3 {
            assert_eq!(core.lane_stats(i), &CoreStats::default());
        }
        assert_eq!(core.analog_energy(), energy_before, "folding changed energy");
    }

    /// reset_lane clears exactly one lane's state (its neighbours' resident
    /// membranes survive) and fold_one_lane moves exactly one lane's
    /// counters to the core.
    #[test]
    fn reset_lane_and_fold_one_lane_are_per_lane() {
        let layer = random_layer(30, 12, 0.4, 71);
        let cfg = small_cfg(4, 3);
        let inputs: Vec<SpikeTrain> =
            (0..3).map(|i| random_input(30, 6, 0.25, 95 + i as u64)).collect();
        let mut core = build_core(&layer, &cfg, true);
        run_core_lanes(&mut core, &inputs);

        let lane0_before: Vec<_> =
            (0..core.rounds()).map(|r| core.lane_slot_states(0, r)).collect();
        let lane1_macs = core.lane_stats(1).macs;
        let lane0_macs = core.lane_stats(0).macs;
        assert!(lane1_macs > 0);

        core.reset_lane(1);
        for r in 0..core.rounds() {
            assert_eq!(core.lane_slot_states(0, r), lane0_before[r], "lane 0 clobbered");
            for (mem, acc, _) in core.lane_slot_states(1, r) {
                assert_eq!(mem, 0.0);
                assert_eq!(acc, 0);
            }
        }
        // Stats survive the reset; fold_one_lane moves only lane 1's.
        assert_eq!(core.lane_stats(1).macs, lane1_macs);
        core.fold_one_lane(1);
        assert_eq!(core.stats.macs, lane1_macs);
        assert_eq!(core.lane_stats(1), &CoreStats::default());
        assert_eq!(core.lane_stats(0).macs, lane0_macs, "lane 0 stats folded too");
    }

    #[test]
    fn engine_count_mismatch_rejected() {
        let layer = random_layer(10, 4, 0.3, 17);
        let cfg4 = small_cfg(4, 2);
        let mp = map_layer(&layer, &cfg4, Strategy::Greedy).unwrap();
        let img = distill(&layer, &mp, &cfg4).unwrap();
        let cfg2 = small_cfg(2, 2);
        let mut rng = Rng::new(1);
        assert!(NeuraCore::new(
            0,
            img,
            layer.lif,
            &AnalogParams::ideal(),
            &cfg2,
            &mut rng
        )
        .is_err());
    }
}
