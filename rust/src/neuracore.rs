//! Cycle-accurate MX-NEURACORE simulator (paper §III, Figures 1 & 4).
//!
//! One MX-NEURACORE executes one model layer. Per global time step the
//! core:
//!
//! 1. latches incoming events into MEM_E on the clock's rising edge;
//! 2. the polling controller pops one event per cycle (unless a previous
//!    event is still being dispatched — "the controller does not fetch any
//!    new event from the MEM_E"), looks up MEM_E2A to find `B_i` MEM_S&N
//!    rows starting at `A_i`;
//! 3. streams those rows, one per cycle: each row drives up to M A-SYN
//!    engines in parallel (C2C MAC) whose charge packets accumulate on the
//!    addressed virtual-neuron capacitors of the M A-NEURONs;
//! 4. at the end of the step the controller sweeps the resident virtual
//!    neurons: leak + integrate + compare-to-threshold → emit spike events
//!    for the next core → reset (the paper's restore/integrate/store plus
//!    the discharge command).
//!
//! Numerics: the charge accumulated during a step is tracked as the exact
//! integer sum of quantized weights (what an ideal C2C ladder deposits);
//! the sweep computes `v ← β·v + Σw·scale` in f32 — *bit-identical* to
//! [`crate::snn::reference_forward`]. Analog non-idealities (C2C mismatch,
//! op-amp saturation, switch injection, hold droop) are carried as a
//! separate additive error term that is exactly zero in
//! [`AnalogParams::ideal`] mode, so ideal-mode equivalence with the
//! reference is structural, not accidental.
//!
//! Rounds: when the layer was mapped in R > 1 rounds (more neurons than
//! M·N capacitors), the controller replays the step's events once per
//! round with the round's MEM image — the paper's capacitor reassignment.
//! Cycle and energy accounting include the replay cost.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::analog::{ASyn, AnalogParams};
use crate::config::AcceleratorConfig;
use crate::mapping::CoreImage;
use crate::snn::LifParams;
use crate::util::rng::Rng;

/// Per-step and cumulative statistics of one core (feeds the energy model
/// and Figures 6–7).
#[derive(Debug, Clone, Default)]
pub struct CoreStats {
    /// Clock cycles consumed, cumulative.
    pub cycles: u64,
    /// Events popped from MEM_E (per-round replays counted once per round).
    pub events_dispatched: u64,
    /// MEM_S&N rows streamed.
    pub sn_rows_read: u64,
    /// Synaptic MACs performed (A-SYN operations).
    pub macs: u64,
    /// A-NEURON integrate operations (one per deposited packet).
    pub integrations: u64,
    /// A-NEURON sweep (restore/compare/store or leak) operations.
    pub fire_ops: u64,
    /// Output spikes emitted.
    pub spikes_out: u64,
    /// MEM_E occupancy high-water mark.
    pub peak_event_queue: usize,
    /// MEM_E overflow drops (backpressure failure).
    pub dropped_events: u64,
    /// Per-time-step MEM_S&N rows *touched* (utilization series for
    /// Figures 6–7).
    pub sn_rows_touched_per_step: Vec<u64>,
    /// Per-time-step cycle counts.
    pub cycles_per_step: Vec<u64>,
}

/// Membrane state of one mapping round: exact f32 membranes plus the
/// step's integer charge accumulator and the analog error sidecar.
#[derive(Debug, Clone)]
struct RoundState {
    /// f32 membrane per slot (j·N + k), reference-exact arithmetic.
    mem: Vec<f32>,
    /// Integer charge accumulated this step (Σ quantized weights).
    acc: Vec<i32>,
    /// Accumulated analog deviation per slot (0 in ideal mode).
    err: Vec<f64>,
}

/// One MX-NEURACORE instance with loaded control memories.
#[derive(Debug, Clone)]
pub struct NeuraCore {
    /// Core index in the chain (= layer index).
    pub index: usize,
    /// Distilled control memories. `Arc`: images are immutable at run time
    /// and large (MEM_S&N rows + weight SRAM), so coordinator workers share
    /// one copy — chip cloning is O(state), not O(model).
    image: Arc<CoreImage>,
    /// Flattened `(slot, dst)` residents per round — the end-of-step sweep
    /// iterates this instead of the BTreeMap (perf pass §Perf item 5).
    residents_flat: Vec<Vec<((u16, u16), u32)>>,
    /// Compact CSR mirror of each round's MEM_S&N: row `r` covers
    /// `row_entries[round][rows_index[round][r] .. rows_index[round][r+1]]`
    /// as `(engine, virt, weight)` — the dispatch loop skips empty engine
    /// columns entirely and reads the weight inline (the silicon's weight-
    /// SRAM read is still priced via the MAC count) (perf §Perf item 2/6).
    rows_index: Vec<Vec<u32>>,
    row_entries: Vec<Vec<(u8, u16, i8)>>,
    lif: LifParams,
    analog: AnalogParams,
    /// A-SYN engines (one per A-NEURON column, paper Figure 1); provide
    /// C2C mismatch modeling and MAC energy accounting.
    syns: Vec<ASyn>,
    /// Per-round membrane state (the "parked" capacitor charge).
    state: Vec<RoundState>,
    /// MEM_E: pending events for the current step.
    event_queue: Vec<u32>,
    event_mem_depth: usize,
    /// Capacitors per A-NEURON (N).
    caps_per_engine: usize,
    pub stats: CoreStats,
    /// Scratch per-engine occupancy counter (hot-path reuse).
    sweep_count: Vec<u64>,
    /// Scratch per-engine MAC counter, flushed to the A-SYN energy
    /// accounts once per step (perf: keeps the dispatch inner loop free of
    /// bookkeeping float adds).
    mac_count: Vec<u64>,
}

impl NeuraCore {
    /// Build a core from a distilled image. `analog` selects ideal vs
    /// paper-calibrated non-ideal circuit behaviour; `rng` seeds per-engine
    /// C2C mismatch when non-ideal.
    pub fn new(
        index: usize,
        image: CoreImage,
        lif: LifParams,
        analog: &AnalogParams,
        cfg: &AcceleratorConfig,
        rng: &mut Rng,
    ) -> Result<Self> {
        if image.num_engines != cfg.a_neurons_per_core {
            bail!(
                "image distilled for {} engines, core has {}",
                image.num_engines,
                cfg.a_neurons_per_core
            );
        }
        let m = cfg.a_neurons_per_core;
        let n = cfg.virtual_per_a_neuron;
        let syns = (0..m)
            .map(|j| {
                let mut fork = rng.fork((index * 1024 + j) as u64);
                ASyn::new(cfg.weight_bits, analog, Some(&mut fork))
            })
            .collect();
        let state = image
            .rounds
            .iter()
            .map(|_| RoundState {
                mem: vec![lif.v_reset; m * n],
                acc: vec![0i32; m * n],
                err: vec![0.0f64; m * n],
            })
            .collect();
        let residents_flat = image
            .rounds
            .iter()
            .map(|r| r.residents.iter().map(|(&s, &d)| (s, d)).collect())
            .collect();
        let mut rows_index = Vec::with_capacity(image.rounds.len());
        let mut row_entries = Vec::with_capacity(image.rounds.len());
        for round in &image.rounds {
            let mut idx = Vec::with_capacity(round.sn_rows.len() + 1);
            let mut entries = Vec::new();
            idx.push(0u32);
            for row in &round.sn_rows {
                for (j, e) in row.per_engine.iter().enumerate() {
                    if let Some(e) = e {
                        entries.push((j as u8, e.virt, image.weight_mem[e.weight_addr as usize]));
                    }
                }
                idx.push(entries.len() as u32);
            }
            rows_index.push(idx);
            row_entries.push(entries);
        }
        Ok(Self {
            index,
            image: Arc::new(image),
            residents_flat,
            rows_index,
            row_entries,
            lif,
            analog: analog.clone(),
            syns,
            state,
            event_queue: Vec::new(),
            event_mem_depth: cfg.event_mem_depth,
            caps_per_engine: n,
            stats: CoreStats::default(),
            sweep_count: vec![0u64; m],
            mac_count: vec![0u64; m],
        })
    }

    /// Number of mapping rounds.
    pub fn rounds(&self) -> usize {
        self.image.rounds.len()
    }

    /// Output (destination-layer) dimensionality.
    pub fn out_dim(&self) -> usize {
        self.image.out_dim
    }

    /// Input (source-layer) dimensionality.
    pub fn in_dim(&self) -> usize {
        self.image.in_dim
    }

    /// Whether the analog model is exactly ideal.
    fn is_ideal(&self) -> bool {
        self.analog.c2c_mismatch_sigma == 0.0
            && self.analog.switch_injection == 0.0
            && self.analog.hold_leak == 0.0
            && !self.analog.v_sat.is_finite()
    }

    /// Latch incoming events (source-neuron indices) into MEM_E. Returns
    /// the number of dropped events if the memory overflows.
    pub fn push_events(&mut self, events: &[u32]) -> usize {
        let space = self.event_mem_depth.saturating_sub(self.event_queue.len());
        let take = events.len().min(space);
        self.event_queue.extend_from_slice(&events[..take]);
        let dropped = events.len() - take;
        self.stats.dropped_events += dropped as u64;
        self.stats.peak_event_queue =
            self.stats.peak_event_queue.max(self.event_queue.len());
        dropped
    }

    /// Execute one global time step: dispatch all latched events through
    /// every round, sweep fire/leak, return the emitted spikes (destination
    /// layer neuron ids, sorted ascending).
    pub fn step(&mut self) -> Vec<u32> {
        let m = self.image.num_engines;
        let n = self.caps_per_engine;
        let scale = self.image.scale;
        let ideal = self.is_ideal();
        let mut out: Vec<u32> = Vec::new();
        let mut cycles_this_step = 0u64;
        let mut rows_this_step = 0u64;

        let num_rounds = self.image.rounds.len();
        for round_idx in 0..num_rounds {
            let round = &self.image.rounds[round_idx];
            let st = &mut self.state[round_idx];
            // Capacitor reassignment cost: reloading parked state for
            // non-resident rounds takes occupied/m cycles of charge
            // transfer.
            if num_rounds > 1 {
                cycles_this_step +=
                    (round.residents.len() as u64).div_ceil(m as u64);
            }

            // Dispatch every latched event through this round's image.
            for &src in &self.event_queue {
                let s = src as usize;
                self.stats.events_dispatched += 1;
                cycles_this_step += 1; // MEM_E pop + MEM_E2A read
                if s >= round.e2a.len() {
                    continue;
                }
                let e2a = round.e2a[s];
                if e2a.count == 0 {
                    continue;
                }
                cycles_this_step += e2a.count as u64; // one MEM_S&N row/cycle
                rows_this_step += e2a.count as u64;
                self.stats.sn_rows_read += e2a.count as u64;
                let ridx = &self.rows_index[round_idx];
                let lo = ridx[e2a.start as usize] as usize;
                let hi = ridx[(e2a.start + e2a.count) as usize] as usize;
                let entries = &self.row_entries[round_idx][lo..hi];
                self.stats.macs += entries.len() as u64;
                self.stats.integrations += entries.len() as u64;
                if ideal {
                    // Ideal C2C deposit: exactly w (integer charge). The
                    // bookkeeping (per-engine MAC energy) is batched into
                    // `mac_count` and flushed once per step.
                    for &(j, virt, w) in entries {
                        st.acc[j as usize * n + virt as usize] += w as i32;
                        self.mac_count[j as usize] += 1;
                    }
                } else {
                    // Analog sidecar: deviation of the real C2C packet
                    // from ideal, plus switch injection per deposit.
                    for &(j, virt, w) in entries {
                        let j = j as usize;
                        let slot = j * n + virt as usize;
                        st.acc[slot] += w as i32;
                        self.mac_count[j] += 1;
                        let real = self.syns[j]
                            .ladder
                            .convert_signed(w, self.analog.v_ref)
                            * 256.0
                            * scale as f64
                            / self.analog.v_ref;
                        let deviation = real - w as f64 * scale as f64;
                        st.err[slot] +=
                            deviation + self.analog.switch_injection * 0.01;
                    }
                }
            }

            // End-of-step sweep for this round: leak + integrate + compare.
            // Engines sweep their occupied capacitors in parallel; cycles =
            // max per-engine occupancy.
            self.sweep_count.fill(0);
            for &((j, k), dst) in &self.residents_flat[round_idx] {
                let (j, k) = (j as usize, k as usize);
                let slot = j * n + k;
                self.sweep_count[j] += 1;
                self.stats.fire_ops += 1;
                // Reference-exact arithmetic (see module docs).
                let mut v =
                    self.lif.beta * st.mem[slot] + st.acc[slot] as f32 * scale;
                if !ideal {
                    // Apply accumulated analog error and hold droop.
                    v += st.err[slot] as f32;
                    v -= (st.mem[slot] * self.analog.hold_leak as f32).abs();
                    if self.analog.v_sat.is_finite() {
                        v = v.clamp(-self.analog.v_sat as f32, self.analog.v_sat as f32);
                    }
                }
                st.acc[slot] = 0;
                st.err[slot] = 0.0;
                if v >= self.lif.v_threshold {
                    out.push(dst);
                    st.mem[slot] = self.lif.v_reset;
                    self.stats.spikes_out += 1;
                } else {
                    st.mem[slot] = v;
                }
            }
            cycles_this_step += self.sweep_count.iter().copied().max().unwrap_or(0);
        }

        // Flush the batched per-engine MAC accounting.
        for (j, &cnt) in self.mac_count.iter().enumerate() {
            if cnt > 0 {
                self.syns[j].macs += cnt;
                self.syns[j].energy += cnt as f64 * self.syns[j].energy_per_mac;
            }
        }
        self.mac_count.fill(0);

        self.event_queue.clear();
        self.stats.cycles += cycles_this_step;
        self.stats.cycles_per_step.push(cycles_this_step);
        self.stats.sn_rows_touched_per_step.push(rows_this_step);
        out.sort_unstable();
        out
    }

    /// Reset membrane state (between inputs) without clearing statistics.
    pub fn reset_membranes(&mut self) {
        for st in self.state.iter_mut() {
            st.mem.fill(self.lif.v_reset);
            st.acc.fill(0);
            st.err.fill(0.0);
        }
        self.event_queue.clear();
    }

    /// Total analog energy consumed so far (J): A-SYN MACs plus A-NEURON
    /// integrate and sweep operations at the paper's per-op energy.
    pub fn analog_energy(&self) -> f64 {
        let mac_energy: f64 = self.syns.iter().map(|s| s.energy).sum();
        let neuron_ops = self.stats.integrations + self.stats.fire_ops;
        mac_energy + neuron_ops as f64 * self.analog.neuron_energy_per_op
    }

    /// MEM_S&N rows present in the image, across rounds.
    pub fn image_sn_rows(&self) -> usize {
        self.image.rounds.iter().map(|r| r.sn_rows.len()).sum()
    }

    /// Weight SRAM bytes used.
    pub fn weight_bytes(&self) -> usize {
        self.image.weight_mem.len()
    }

    /// A-SYN MAC energy constant (J) — exposed for the energy model.
    pub fn mac_energy(&self) -> f64 {
        self.syns[0].energy_per_mac
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::mapping::{distill, map_layer, Strategy};
    use crate::snn::{reference_forward, LifParams, QuantLayer, QuantNetwork, SpikeTrain};
    use crate::util::rng::Rng;

    fn small_cfg(m: usize, n: usize) -> AcceleratorConfig {
        let mut c = AcceleratorConfig::accel1();
        c.a_neurons_per_core = m;
        c.a_syns_per_core = m;
        c.virtual_per_a_neuron = n;
        c
    }

    fn build_core(layer: &QuantLayer, cfg: &AcceleratorConfig, ideal: bool) -> NeuraCore {
        let mp = map_layer(layer, cfg, Strategy::IlpFlow).unwrap();
        mp.validate(layer, cfg).unwrap();
        let img = distill(layer, &mp, cfg).unwrap();
        let analog = if ideal { AnalogParams::ideal() } else { AnalogParams::paper() };
        let mut rng = Rng::new(99);
        NeuraCore::new(0, img, layer.lif, &analog, cfg, &mut rng).unwrap()
    }

    fn run_core(core: &mut NeuraCore, input: &SpikeTrain) -> SpikeTrain {
        let mut out = SpikeTrain::new(core.out_dim(), input.timesteps());
        for t in 0..input.timesteps() {
            core.push_events(&input.spikes[t]);
            out.spikes[t] = core.step();
        }
        out
    }

    fn random_layer(in_dim: usize, out_dim: usize, sparsity: f64, seed: u64) -> QuantLayer {
        let mut rng = Rng::new(seed);
        let mut w = vec![0i8; in_dim * out_dim];
        for x in w.iter_mut() {
            if !rng.bernoulli(sparsity) {
                *x = rng.range_inclusive(-127, 127) as i8;
            }
        }
        QuantLayer::new(
            in_dim,
            out_dim,
            w,
            0.02,
            LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 },
        )
        .unwrap()
    }

    fn random_input(dim: usize, t: usize, rate: f64, seed: u64) -> SpikeTrain {
        let mut rng = Rng::new(seed);
        let mut st = SpikeTrain::new(dim, t);
        for step in st.spikes.iter_mut() {
            for i in 0..dim {
                if rng.bernoulli(rate) {
                    step.push(i as u32);
                }
            }
        }
        st
    }

    /// The core in ideal-analog mode must match the reference bit-exactly.
    #[test]
    fn core_matches_reference_single_round() {
        let layer = random_layer(30, 12, 0.4, 1);
        let cfg = small_cfg(4, 4); // capacity 16 ≥ 12: single round
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 12 };
        let input = random_input(30, 12, 0.15, 2);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "ideal core ≠ reference");
        assert!(core.stats.macs > 0);
        assert!(core.stats.cycles > 0);
    }

    /// Multi-round mapping (more neurons than capacitors) must also match.
    #[test]
    fn core_matches_reference_multi_round() {
        let layer = random_layer(20, 30, 0.5, 3);
        let cfg = small_cfg(3, 4); // capacity 12 < 30: ≥3 rounds
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 10 };
        let input = random_input(20, 10, 0.2, 4);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        assert!(core.rounds() >= 3);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "multi-round ≠ reference");
    }

    /// Property: ideal equivalence holds across many random instances.
    #[test]
    fn prop_ideal_equivalence() {
        crate::util::prop::check_n("core-ref-equivalence", 20, |rng| {
            let in_dim = 5 + rng.below(30);
            let out_dim = 3 + rng.below(25);
            let m = 2 + rng.below(4);
            let n = 1 + rng.below(5);
            let layer = random_layer(in_dim, out_dim, 0.3 + rng.f64() * 0.5, rng.next_u64());
            let cfg = small_cfg(m, n);
            let t = 4 + rng.below(8);
            let input = random_input(in_dim, t, 0.1 + rng.f64() * 0.3, rng.next_u64());
            let net = QuantNetwork { name: "p".into(), layers: vec![layer.clone()], timesteps: t };
            let golden = reference_forward(&net, &input).map_err(|e| e.to_string())?;
            let mut core = build_core(&layer, &cfg, true);
            let out = run_core(&mut core, &input);
            if out.spikes != golden.output().spikes {
                return Err(format!(
                    "divergence: m={m} n={n} in={in_dim} out={out_dim} t={t}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn mismatch_only_mode_close_to_reference() {
        // C2C mismatch alone (no rail clamp, no injection, no droop) must
        // perturb spike counts by only a few percent.
        let layer = random_layer(40, 16, 0.4, 5);
        let cfg = small_cfg(4, 4);
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 20 };
        let input = random_input(40, 20, 0.15, 6);
        let golden = reference_forward(&net, &input).unwrap();
        let mut analog = AnalogParams::ideal();
        analog.c2c_mismatch_sigma = 0.002;
        let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        let img = distill(&layer, &mp, &cfg).unwrap();
        let mut rng = Rng::new(99);
        let mut core = NeuraCore::new(0, img, layer.lif, &analog, &cfg, &mut rng).unwrap();
        let out = run_core(&mut core, &input);
        let g = golden.output().total_spikes() as f64;
        let o = out.total_spikes() as f64;
        assert!(
            (o - g).abs() <= (0.10 * g).max(2.0),
            "mismatch-only spikes {o} too far from golden {g}"
        );
    }

    #[test]
    fn paper_analog_mode_same_order_as_reference() {
        // Full non-ideal mode adds the supply-rail clamp, which the
        // rail-less reference cannot reproduce: membranes that would drift
        // deeply negative recover sooner, so the count shifts — but must
        // stay within the same order (factor ~2) and the core must still
        // be live.
        let layer = random_layer(40, 16, 0.4, 5);
        let cfg = small_cfg(4, 4);
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 20 };
        let input = random_input(40, 20, 0.15, 6);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, false);
        let out = run_core(&mut core, &input);
        let g = golden.output().total_spikes() as f64;
        let o = out.total_spikes() as f64;
        assert!(o > 0.0);
        assert!(o <= 2.5 * g && o >= g / 2.5, "non-ideal spikes {o} vs golden {g}");
    }

    #[test]
    fn cycles_scale_with_activity() {
        let layer = random_layer(30, 10, 0.3, 7);
        let cfg = small_cfg(5, 2);
        let quiet = random_input(30, 10, 0.02, 8);
        let busy = random_input(30, 10, 0.5, 9);
        let mut c1 = build_core(&layer, &cfg, true);
        run_core(&mut c1, &quiet);
        let mut c2 = build_core(&layer, &cfg, true);
        run_core(&mut c2, &busy);
        assert!(
            c2.stats.cycles > c1.stats.cycles,
            "busy {} ≤ quiet {}",
            c2.stats.cycles,
            c1.stats.cycles
        );
        assert!(c2.stats.sn_rows_read > c1.stats.sn_rows_read);
    }

    #[test]
    fn event_memory_overflow_drops() {
        let layer = random_layer(100, 4, 0.5, 10);
        let mut cfg = small_cfg(2, 2);
        cfg.event_mem_depth = 8;
        let mut core = build_core(&layer, &cfg, true);
        let events: Vec<u32> = (0..20).collect();
        let dropped = core.push_events(&events);
        assert_eq!(dropped, 12);
        assert_eq!(core.stats.dropped_events, 12);
        assert_eq!(core.stats.peak_event_queue, 8);
    }

    #[test]
    fn reset_membranes_clears_state_keeps_stats() {
        let layer = random_layer(20, 8, 0.3, 11);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, true);
        let input = random_input(20, 6, 0.3, 12);
        run_core(&mut core, &input);
        let cycles = core.stats.cycles;
        assert!(cycles > 0);
        core.reset_membranes();
        assert_eq!(core.stats.cycles, cycles, "stats must survive reset");
        // State is cleared: a silent step emits nothing.
        let out = core.step();
        assert!(out.is_empty());
    }

    #[test]
    fn per_step_series_lengths_match() {
        let layer = random_layer(20, 8, 0.3, 13);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, true);
        let input = random_input(20, 7, 0.2, 14);
        run_core(&mut core, &input);
        // 7 event steps + 1 silent step from reset test? No: exactly 7.
        assert_eq!(core.stats.cycles_per_step.len(), 7);
        assert_eq!(core.stats.sn_rows_touched_per_step.len(), 7);
        assert_eq!(
            core.stats.cycles_per_step.iter().sum::<u64>(),
            core.stats.cycles
        );
    }

    #[test]
    fn analog_energy_accumulates() {
        let layer = random_layer(20, 8, 0.3, 15);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, false);
        assert_eq!(core.analog_energy(), 0.0);
        let input = random_input(20, 5, 0.3, 16);
        run_core(&mut core, &input);
        assert!(core.analog_energy() > 0.0);
        let expected = (core.stats.integrations + core.stats.fire_ops) as f64
            * AnalogParams::paper().neuron_energy_per_op
            + core.stats.macs as f64 * core.mac_energy();
        assert!((core.analog_energy() - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn engine_count_mismatch_rejected() {
        let layer = random_layer(10, 4, 0.3, 17);
        let cfg4 = small_cfg(4, 2);
        let mp = map_layer(&layer, &cfg4, Strategy::Greedy).unwrap();
        let img = distill(&layer, &mp, &cfg4).unwrap();
        let cfg2 = small_cfg(2, 2);
        let mut rng = Rng::new(1);
        assert!(NeuraCore::new(
            0,
            img,
            layer.lif,
            &AnalogParams::ideal(),
            &cfg2,
            &mut rng
        )
        .is_err());
    }
}
