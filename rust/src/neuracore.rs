//! Cycle-accurate MX-NEURACORE simulator (paper §III, Figures 1 & 4).
//!
//! One MX-NEURACORE executes one model layer. Per global time step the
//! core:
//!
//! 1. latches incoming events into MEM_E on the clock's rising edge;
//! 2. the polling controller pops one event per cycle (unless a previous
//!    event is still being dispatched — "the controller does not fetch any
//!    new event from the MEM_E"), looks up MEM_E2A to find `B_i` MEM_S&N
//!    rows starting at `A_i`;
//! 3. streams those rows, one per cycle: each row drives up to M A-SYN
//!    engines in parallel (C2C MAC) whose charge packets accumulate on the
//!    addressed virtual-neuron capacitors of the M A-NEURONs;
//! 4. at the end of the step the controller sweeps the resident virtual
//!    neurons: leak + integrate + compare-to-threshold → emit spike events
//!    for the next core → reset (the paper's restore/integrate/store plus
//!    the discharge command).
//!
//! Numerics: the charge accumulated during a step is tracked as the exact
//! integer sum of quantized weights (what an ideal C2C ladder deposits);
//! the sweep computes `v ← β·v + Σw·scale` in f32 — *bit-identical* to
//! [`crate::snn::reference_forward`]. Analog non-idealities (C2C mismatch,
//! op-amp saturation, switch injection, hold droop) are carried as a
//! separate additive error term that is exactly zero in
//! [`AnalogParams::ideal`] mode, so ideal-mode equivalence with the
//! reference is structural, not accidental.
//!
//! Rounds: when the layer was mapped in R > 1 rounds (more neurons than
//! M·N capacitors), the controller replays the step's events once per
//! round with the round's MEM image — the paper's capacitor reassignment.
//! Cycle and energy accounting include the replay cost.
//!
//! # Perf pass: activity-tracked sweep and event coalescing
//!
//! The simulator's wall-clock cost tracks *activity* (spikes), not
//! *capacity* (residents). Two invariant-preserving shortcuts:
//!
//! * **Activity-tracked sweep.** Each round keeps a per-slot dirty flag:
//!   a slot is dirty when its state differs from the quiescent fixed point
//!   (`mem == v_reset`, `acc == 0`, `err == 0`). The end-of-step sweep
//!   *skips the arithmetic* for clean slots — valid only when the leak is
//!   provably a no-op at the fixed point (`β·v_reset == v_reset` bit-exact
//!   in f32, below threshold, zero hold droop), which `sweep_skip` checks
//!   once at construction; otherwise every slot stays permanently dirty
//!   and the sweep is dense, bit-identical to the naive loop. **What must
//!   still be counted:** the hardware sweeps every occupied capacitor
//!   regardless of charge, so `fire_ops` charges one op per resident per
//!   step and the sweep's cycle cost stays the per-round max engine
//!   occupancy (precomputed — occupancy is static). Only simulator-side
//!   arithmetic is elided; no [`CoreStats`] counter changes.
//! * **Event coalescing.** In ideal-analog mode duplicate MEM_E entries
//!   for the same source are dispatched as (event, multiplicity): the
//!   CSR row slice is streamed once and deposits `w·mult` (exact in i32).
//!   **What must still be counted:** the controller pops each event
//!   individually, so `events_dispatched`, `cycles`, `sn_rows_read`,
//!   `macs` and `integrations` are all charged ×multiplicity. Non-ideal
//!   mode dispatches per event (the error sidecar is per-deposit).
//!
//! Residents are iterated in destination-id order, so each round emits its
//! spikes pre-sorted and the common single-round case needs no output sort.
//!
//! # Lane execution (SIMD-style batching)
//!
//! One virtual-neuron engine is time-multiplexed over many model neurons;
//! the same insight applies one level up: the MEM_E2A lookup and MEM_S&N
//! rows streamed for an input event are *identical for every sample*, so a
//! batch of B independent samples can share one CSR walk. A [`CoreLane`]
//! holds everything that is per-sample — per-round [`RoundState`]
//! (membranes, charge accumulators, dirty flags; all slot-indexed exactly
//! like the sequential path), the MEM_E queue, and a private [`CoreStats`]
//! — while the distilled [`CoreImage`], CSR mirror, resident lists and
//! sweep costs stay shared and immutable behind the core.
//!
//! Invariants the lane path maintains (pinned by
//! `tests/lanes_differential.rs` against the sequential engine):
//!
//! * **Shared image, per-lane state.** [`Self::step_lanes_into`] walks the
//!   merged, ascending stream of distinct `(src, multiplicity)` runs across
//!   all active lanes and fetches each event's MEM_E2A entry and MEM_S&N
//!   row slice **once**, depositing into every lane that carries the event.
//!   Deposits are exact integer adds, so the traversal order shared across
//!   lanes cannot change any lane's membrane arithmetic.
//! * **Per-lane stats attribution.** Every [`CoreStats`] counter — cycles
//!   (including per-round reassignment and sweep costs), events, rows,
//!   MACs, integrations, fire ops, spikes, the per-step series — is charged
//!   to each carrying lane exactly as the sequential dispatch would charge
//!   it, ×multiplicity. Per-lane stats are **bit-identical** to running the
//!   lane's input through a fresh sequential core. Only the A-SYN energy
//!   accounts are core-level (summed across lanes, flushed once per step).
//! * **Exactness gate.** The shared walk requires the coalescing
//!   precondition (ideal analog mode): the non-ideal error sidecar is
//!   per-deposit and order-sensitive in f64, so non-ideal mode (or
//!   `force_per_event_dispatch`) routes every lane through the *actual
//!   sequential* `step_into` — the lane's state is swapped into the core,
//!   stepped, and swapped back — making equivalence structural.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::analog::{ASyn, AnalogParams};
use crate::config::AcceleratorConfig;
use crate::mapping::CoreImage;
use crate::snn::LifParams;
use crate::util::rng::Rng;

/// Bound on the per-step statistic series in [`CoreStats`]
/// (`cycles_per_step`, `sn_rows_touched_per_step`). The series exist for
/// figure generation over short runs; a long-lived coordinator service
/// processes an unbounded request stream, and without a cap each lane's
/// series would grow by `2·T` entries per request forever. Recording
/// simply stops at the cap (both engines apply it identically, so
/// lane/sequential bit-identity is unaffected); the scalar totals keep
/// accumulating.
pub const STEP_SERIES_CAP: usize = 1 << 20;

/// Per-step and cumulative statistics of one core (feeds the energy model
/// and Figures 6–7).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CoreStats {
    /// Clock cycles consumed, cumulative.
    pub cycles: u64,
    /// Events popped from MEM_E (per-round replays counted once per round).
    pub events_dispatched: u64,
    /// MEM_S&N rows streamed.
    pub sn_rows_read: u64,
    /// Synaptic MACs performed (A-SYN operations).
    pub macs: u64,
    /// A-NEURON integrate operations (one per deposited packet).
    pub integrations: u64,
    /// A-NEURON sweep (restore/compare/store or leak) operations.
    pub fire_ops: u64,
    /// Output spikes emitted.
    pub spikes_out: u64,
    /// MEM_E occupancy high-water mark.
    pub peak_event_queue: usize,
    /// MEM_E overflow drops (backpressure failure).
    pub dropped_events: u64,
    /// Per-time-step MEM_S&N rows *touched* (utilization series for
    /// Figures 6–7).
    pub sn_rows_touched_per_step: Vec<u64>,
    /// Per-time-step cycle counts.
    pub cycles_per_step: Vec<u64>,
}

/// Membrane state of one mapping round: exact f32 membranes plus the
/// step's integer charge accumulator and the analog error sidecar.
#[derive(Debug, Clone)]
struct RoundState {
    /// f32 membrane per slot (j·N + k), reference-exact arithmetic.
    mem: Vec<f32>,
    /// Integer charge accumulated this step (Σ quantized weights).
    acc: Vec<i32>,
    /// Accumulated analog deviation per slot (0 in ideal mode).
    err: Vec<f64>,
    /// Activity tracking (perf §module docs): `true` when the slot's state
    /// differs from the quiescent fixed point and the sweep must do full
    /// arithmetic. All-`true` forever when `sweep_skip` is disabled.
    dirty: Vec<bool>,
}

impl RoundState {
    /// Quiescent state for `slots` capacitors (all membranes parked at
    /// `v_reset`, nothing accumulated, dirty iff skipping is disabled).
    fn fresh(slots: usize, v_reset: f32, sweep_skip: bool) -> Self {
        Self {
            mem: vec![v_reset; slots],
            acc: vec![0i32; slots],
            err: vec![0.0f64; slots],
            dirty: vec![!sweep_skip; slots],
        }
    }

    /// Reset to the quiescent state in place (buffers reused).
    fn reset(&mut self, v_reset: f32, sweep_skip: bool) {
        self.mem.fill(v_reset);
        self.acc.fill(0);
        self.err.fill(0.0);
        self.dirty.fill(!sweep_skip);
    }
}

/// Per-lane execution state: everything one batched sample owns privately
/// while sharing the core's immutable image (module docs §Lane execution).
#[derive(Debug, Clone, Default)]
pub struct CoreLane {
    /// Per-round membrane state, slot-indexed like the sequential path.
    state: Vec<RoundState>,
    /// This lane's MEM_E: pending events for the current step.
    event_queue: Vec<u32>,
    /// Scratch: the queue coalesced into ascending `(src, multiplicity)`
    /// runs, rebuilt each step and replayed per round.
    runs: Vec<(u32, u32)>,
    /// Per-lane statistics, attributed exactly as the sequential engine
    /// would (module docs).
    pub stats: CoreStats,
}

/// Whether `v_reset` is a quiescent fixed point of the sweep: a slot with
/// `mem == v_reset`, `acc == 0`, `err == 0` must come out of the full
/// leak/integrate/compare arithmetic bit-identical and below threshold.
/// When this holds the sweep may skip clean slots (module docs); when it
/// does not (e.g. `β·v_reset != v_reset`), skipping is disabled and every
/// slot stays dirty forever.
fn quiescent_fixed_point(lif: &LifParams, analog: &AnalogParams) -> bool {
    let ideal = analog.is_ideal();
    let q = lif.v_reset;
    // Mirror the sweep arithmetic exactly, with acc == 0 and err == 0.
    let mut v = lif.beta * q;
    if !ideal {
        v -= (q * analog.hold_leak as f32).abs();
        if analog.v_sat.is_finite() {
            v = v.clamp(-analog.v_sat as f32, analog.v_sat as f32);
        }
    }
    v == q && v < lif.v_threshold
}

/// The MEM_E latch, shared by the sequential and lane paths so the
/// overflow policy (append up to the memory depth, drop the rest, count
/// drops and the occupancy high-water mark) cannot diverge between them.
fn latch_events(
    queue: &mut Vec<u32>,
    stats: &mut CoreStats,
    depth: usize,
    events: &[u32],
) -> usize {
    let space = depth.saturating_sub(queue.len());
    let take = events.len().min(space);
    queue.extend_from_slice(&events[..take]);
    let dropped = events.len() - take;
    stats.dropped_events += dropped as u64;
    stats.peak_event_queue = stats.peak_event_queue.max(queue.len());
    dropped
}

/// One MX-NEURACORE instance with loaded control memories.
#[derive(Debug, Clone)]
pub struct NeuraCore {
    /// Core index in the chain (= layer index).
    pub index: usize,
    /// Distilled control memories. `Arc`: images are immutable at run time
    /// and large (MEM_S&N rows + weight SRAM), so coordinator workers share
    /// one copy — chip cloning is O(state), not O(model).
    image: Arc<CoreImage>,
    /// Flattened `(slot = j·N+k, dst)` residents per round, **sorted by
    /// destination id** so the sweep emits spikes pre-sorted (see module
    /// docs) — iterated instead of the BTreeMap.
    residents_sorted: Vec<Vec<(u32, u32)>>,
    /// Per-round sweep cycle cost (max per-engine occupancy) — static,
    /// precomputed.
    sweep_cost: Vec<u64>,
    /// Whether the quiescent fixed point allows skipping clean slots in the
    /// sweep (see module docs).
    sweep_skip: bool,
    /// Compact CSR mirror of each round's MEM_S&N: row `r` covers
    /// `row_entries[round][rows_index[round][r] .. rows_index[round][r+1]]`
    /// as `(engine, virt, weight)` — the dispatch loop skips empty engine
    /// columns entirely and reads the weight inline (the silicon's weight-
    /// SRAM read is still priced via the MAC count) (perf §Perf item 2/6).
    rows_index: Vec<Vec<u32>>,
    row_entries: Vec<Vec<(u8, u16, i8)>>,
    lif: LifParams,
    analog: AnalogParams,
    /// A-SYN engines (one per A-NEURON column, paper Figure 1); provide
    /// C2C mismatch modeling and MAC energy accounting.
    syns: Vec<ASyn>,
    /// Per-round membrane state (the "parked" capacitor charge) of the
    /// sequential path.
    state: Vec<RoundState>,
    /// Lane-mode state: per-lane membranes/queues/stats behind the shared
    /// image (module docs §Lane execution). Empty until
    /// [`Self::ensure_lanes`] configures a batch width.
    lanes: Vec<CoreLane>,
    /// MEM_E: pending events for the current step.
    event_queue: Vec<u32>,
    event_mem_depth: usize,
    /// Capacitors per A-NEURON (N).
    caps_per_engine: usize,
    pub stats: CoreStats,
    /// Scratch per-engine MAC counter, flushed to the A-SYN energy
    /// accounts once per step (perf: keeps the dispatch inner loop free of
    /// bookkeeping float adds).
    mac_count: Vec<u64>,
    /// Lane-step scratch (one slot per *active* lane, reused across steps
    /// so the lane hot path allocates nothing): per-lane cycle and row
    /// accumulators plus the merge cursor into each lane's run list.
    lane_cycles_scratch: Vec<u64>,
    lane_rows_scratch: Vec<u64>,
    lane_pos_scratch: Vec<usize>,
    /// Test/debug knob: do full sweep arithmetic for every resident slot,
    /// ignoring the dirty flags (the pre-perf-pass behaviour). Used by the
    /// differential regression tests; keep `false` in production.
    pub force_dense_sweep: bool,
    /// Test/debug knob: dispatch each MEM_E entry individually instead of
    /// coalescing duplicates. Used by the differential regression tests.
    pub force_per_event_dispatch: bool,
}

impl NeuraCore {
    /// Build a core from a distilled image. `analog` selects ideal vs
    /// paper-calibrated non-ideal circuit behaviour; `rng` seeds per-engine
    /// C2C mismatch when non-ideal.
    pub fn new(
        index: usize,
        image: CoreImage,
        lif: LifParams,
        analog: &AnalogParams,
        cfg: &AcceleratorConfig,
        rng: &mut Rng,
    ) -> Result<Self> {
        if image.num_engines != cfg.a_neurons_per_core {
            bail!(
                "image distilled for {} engines, core has {}",
                image.num_engines,
                cfg.a_neurons_per_core
            );
        }
        let m = cfg.a_neurons_per_core;
        let n = cfg.virtual_per_a_neuron;
        let syns = (0..m)
            .map(|j| {
                let mut fork = rng.fork((index * 1024 + j) as u64);
                ASyn::new(cfg.weight_bits, analog, Some(&mut fork))
            })
            .collect();
        let sweep_skip = quiescent_fixed_point(&lif, analog);
        let state = image
            .rounds
            .iter()
            .map(|_| RoundState::fresh(m * n, lif.v_reset, sweep_skip))
            .collect();
        let residents_sorted: Vec<Vec<(u32, u32)>> = image
            .rounds
            .iter()
            .map(|r| {
                let mut v: Vec<(u32, u32)> = r
                    .residents
                    .iter()
                    .map(|(&(j, k), &d)| ((j as usize * n + k as usize) as u32, d))
                    .collect();
                v.sort_unstable_by_key(|&(_, d)| d);
                v
            })
            .collect();
        let sweep_cost: Vec<u64> = image
            .rounds
            .iter()
            .map(|r| {
                let mut per_engine = vec![0u64; m];
                for (&(j, _), _) in r.residents.iter() {
                    per_engine[j as usize] += 1;
                }
                per_engine.into_iter().max().unwrap_or(0)
            })
            .collect();
        let mut rows_index = Vec::with_capacity(image.rounds.len());
        let mut row_entries = Vec::with_capacity(image.rounds.len());
        for round in &image.rounds {
            let mut idx = Vec::with_capacity(round.sn_rows.len() + 1);
            let mut entries = Vec::new();
            idx.push(0u32);
            for row in &round.sn_rows {
                for (j, e) in row.per_engine.iter().enumerate() {
                    if let Some(e) = e {
                        entries.push((j as u8, e.virt, image.weight_mem[e.weight_addr as usize]));
                    }
                }
                idx.push(entries.len() as u32);
            }
            rows_index.push(idx);
            row_entries.push(entries);
        }
        Ok(Self {
            index,
            image: Arc::new(image),
            residents_sorted,
            sweep_cost,
            sweep_skip,
            rows_index,
            row_entries,
            lif,
            analog: analog.clone(),
            syns,
            state,
            lanes: Vec::new(),
            event_queue: Vec::new(),
            event_mem_depth: cfg.event_mem_depth,
            caps_per_engine: n,
            stats: CoreStats::default(),
            mac_count: vec![0u64; m],
            lane_cycles_scratch: Vec::new(),
            lane_rows_scratch: Vec::new(),
            lane_pos_scratch: Vec::new(),
            force_dense_sweep: false,
            force_per_event_dispatch: false,
        })
    }

    /// Number of mapping rounds.
    pub fn rounds(&self) -> usize {
        self.image.rounds.len()
    }

    /// Output (destination-layer) dimensionality.
    pub fn out_dim(&self) -> usize {
        self.image.out_dim
    }

    /// Input (source-layer) dimensionality.
    pub fn in_dim(&self) -> usize {
        self.image.in_dim
    }

    /// Whether the analog model is exactly ideal (shared predicate:
    /// [`AnalogParams::is_ideal`]).
    fn is_ideal(&self) -> bool {
        self.analog.is_ideal()
    }

    /// Latch incoming events (source-neuron indices) into MEM_E. Returns
    /// the number of dropped events if the memory overflows.
    pub fn push_events(&mut self, events: &[u32]) -> usize {
        latch_events(&mut self.event_queue, &mut self.stats, self.event_mem_depth, events)
    }

    /// Execute one global time step: dispatch all latched events through
    /// every round, sweep fire/leak, return the emitted spikes (destination
    /// layer neuron ids, sorted ascending).
    ///
    /// Allocates a fresh output vector; the hot path ([`crate::accel`])
    /// uses [`Self::step_into`] with a reused buffer instead.
    pub fn step(&mut self) -> Vec<u32> {
        let mut out = Vec::new();
        self.step_into(&mut out);
        out
    }

    /// [`Self::step`] writing the emitted spikes into a caller-owned buffer
    /// (cleared first) — allocation-free on the steady state.
    pub fn step_into(&mut self, out: &mut Vec<u32>) {
        out.clear();
        let m = self.image.num_engines;
        let n = self.caps_per_engine;
        let scale = self.image.scale;
        let ideal = self.is_ideal();
        // Duplicate-event coalescing is exact only for the integer charge
        // path; the analog sidecar models per-deposit effects (module docs).
        let coalesce = ideal && !self.force_per_event_dispatch;
        let mut cycles_this_step = 0u64;
        let mut rows_this_step = 0u64;

        let mut queue = std::mem::take(&mut self.event_queue);
        if coalesce && queue.len() > 1 && !queue.windows(2).all(|w| w[0] <= w[1]) {
            queue.sort_unstable();
        }

        let num_rounds = self.image.rounds.len();
        for round_idx in 0..num_rounds {
            let round = &self.image.rounds[round_idx];
            let st = &mut self.state[round_idx];
            let residents = &self.residents_sorted[round_idx];
            // Capacitor reassignment cost: reloading parked state for
            // non-resident rounds takes occupied/m cycles of charge
            // transfer.
            if num_rounds > 1 {
                cycles_this_step += (residents.len() as u64).div_ceil(m as u64);
            }

            // Dispatch every latched event through this round's image,
            // duplicates as (event, multiplicity) runs when coalescing.
            let ridx = &self.rows_index[round_idx];
            let ents = &self.row_entries[round_idx];
            let mut i = 0usize;
            while i < queue.len() {
                let src = queue[i];
                let mult = if coalesce {
                    let mut c = 1usize;
                    while i + c < queue.len() && queue[i + c] == src {
                        c += 1;
                    }
                    c
                } else {
                    1
                };
                i += mult;
                let mult_u = mult as u64;
                let s = src as usize;
                // The controller pops each event individually: all costs
                // are charged per dispatched event (×mult).
                self.stats.events_dispatched += mult_u;
                cycles_this_step += mult_u; // MEM_E pop + MEM_E2A read
                if s >= round.e2a.len() {
                    continue;
                }
                let e2a = round.e2a[s];
                if e2a.count == 0 {
                    continue;
                }
                cycles_this_step += mult_u * e2a.count as u64; // one MEM_S&N row/cycle
                rows_this_step += mult_u * e2a.count as u64;
                self.stats.sn_rows_read += mult_u * e2a.count as u64;
                let lo = ridx[e2a.start as usize] as usize;
                let hi = ridx[(e2a.start + e2a.count) as usize] as usize;
                let entries = &ents[lo..hi];
                self.stats.macs += mult_u * entries.len() as u64;
                self.stats.integrations += mult_u * entries.len() as u64;
                if ideal {
                    // Ideal C2C deposit: exactly w·mult (integer charge,
                    // exact). The bookkeeping (per-engine MAC energy) is
                    // batched into `mac_count` and flushed once per step.
                    for &(j, virt, w) in entries {
                        let slot = j as usize * n + virt as usize;
                        st.acc[slot] += w as i32 * mult as i32;
                        st.dirty[slot] = true;
                        self.mac_count[j as usize] += mult_u;
                    }
                } else {
                    // Analog sidecar: deviation of the real C2C packet
                    // from ideal, plus switch injection per deposit
                    // (mult == 1 on this path).
                    for &(j, virt, w) in entries {
                        let j = j as usize;
                        let slot = j * n + virt as usize;
                        st.acc[slot] += w as i32;
                        st.dirty[slot] = true;
                        self.mac_count[j] += 1;
                        let real = self.syns[j]
                            .ladder
                            .convert_signed(w, self.analog.v_ref)
                            * 256.0
                            * scale as f64
                            / self.analog.v_ref;
                        let deviation = real - w as f64 * scale as f64;
                        st.err[slot] +=
                            deviation + self.analog.switch_injection * 0.01;
                    }
                }
            }

            // End-of-step sweep for this round: leak + integrate + compare.
            // The hardware sweeps every occupied capacitor — `fire_ops` and
            // the cycle cost (max per-engine occupancy, static) charge all
            // residents — but the simulator only does the arithmetic for
            // dirty slots (module docs: activity-tracked sweep).
            self.stats.fire_ops += residents.len() as u64;
            let skip = self.sweep_skip;
            let q = self.lif.v_reset;
            for &(slot, dst) in residents {
                let slot = slot as usize;
                if !self.force_dense_sweep && !st.dirty[slot] {
                    continue; // provably a no-op (quiescent fixed point)
                }
                // Reference-exact arithmetic (see module docs).
                let mut v =
                    self.lif.beta * st.mem[slot] + st.acc[slot] as f32 * scale;
                if !ideal {
                    // Apply accumulated analog error and hold droop.
                    v += st.err[slot] as f32;
                    v -= (st.mem[slot] * self.analog.hold_leak as f32).abs();
                    if self.analog.v_sat.is_finite() {
                        v = v.clamp(-self.analog.v_sat as f32, self.analog.v_sat as f32);
                    }
                }
                st.acc[slot] = 0;
                st.err[slot] = 0.0;
                if v >= self.lif.v_threshold {
                    out.push(dst);
                    st.mem[slot] = q;
                    self.stats.spikes_out += 1;
                    // Post-fire state is (v_reset, 0, 0): clean iff that is
                    // the quiescent fixed point.
                    st.dirty[slot] = !skip;
                } else {
                    st.mem[slot] = v;
                    st.dirty[slot] = !(skip && v == q);
                }
            }
            cycles_this_step += self.sweep_cost[round_idx];
        }

        // Flush the batched per-engine MAC accounting.
        for (j, &cnt) in self.mac_count.iter().enumerate() {
            if cnt > 0 {
                self.syns[j].macs += cnt;
                self.syns[j].energy += cnt as f64 * self.syns[j].energy_per_mac;
            }
        }
        self.mac_count.fill(0);

        queue.clear();
        self.event_queue = queue; // hand the (empty) buffer back for reuse
        self.stats.cycles += cycles_this_step;
        if self.stats.cycles_per_step.len() < STEP_SERIES_CAP {
            self.stats.cycles_per_step.push(cycles_this_step);
            self.stats.sn_rows_touched_per_step.push(rows_this_step);
        }
        // Each round emits in ascending dst order; with one round the
        // output is already sorted. Multi-round interleavings are rare —
        // sort only when actually violated.
        if num_rounds > 1 && !out.windows(2).all(|w| w[0] <= w[1]) {
            out.sort_unstable();
        }
    }

    /// Reset membrane state (between inputs) without clearing statistics.
    pub fn reset_membranes(&mut self) {
        for st in self.state.iter_mut() {
            st.reset(self.lif.v_reset, self.sweep_skip);
        }
        self.event_queue.clear();
    }

    // -----------------------------------------------------------------
    // Lane execution (module docs §Lane execution)
    // -----------------------------------------------------------------

    /// Configure the core for at least `b` lanes. Lanes only ever *grow*:
    /// a smaller batch leaves the extra lanes (and, crucially, their
    /// accumulated [`CoreStats`] — which feed [`Self::analog_energy`] and
    /// the coordinator's shutdown accounting) in place; new lanes start
    /// quiescent. Lane identity is positional: lane `i` of a batch maps to
    /// `lanes[i]` across repeated runs.
    pub fn ensure_lanes(&mut self, b: usize) {
        let slots = self.image.num_engines * self.caps_per_engine;
        let rounds = self.image.rounds.len();
        while self.lanes.len() < b {
            self.lanes.push(CoreLane::default());
        }
        for lane in &mut self.lanes {
            if lane.state.len() != rounds {
                lane.state = (0..rounds)
                    .map(|_| RoundState::fresh(slots, self.lif.v_reset, self.sweep_skip))
                    .collect();
            }
        }
    }

    /// Number of configured lanes.
    pub fn num_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Reset every lane's membrane state (between batches) without
    /// clearing the per-lane statistics — the lane analogue of
    /// [`Self::reset_membranes`].
    pub fn reset_lanes(&mut self) {
        for lane in self.lanes.iter_mut() {
            for st in lane.state.iter_mut() {
                st.reset(self.lif.v_reset, self.sweep_skip);
            }
            lane.event_queue.clear();
        }
    }

    /// Per-lane statistics (bit-identical to a fresh sequential core fed
    /// the same input — see module docs).
    pub fn lane_stats(&self, lane: usize) -> &CoreStats {
        &self.lanes[lane].stats
    }

    /// Latch incoming events into lane `lane`'s MEM_E — the same latch
    /// policy as [`Self::push_events`] (one shared helper keeps the
    /// overflow semantics lockstep), against the lane's private queue and
    /// stats.
    pub fn push_events_lane(&mut self, lane: usize, events: &[u32]) -> usize {
        let depth = self.event_mem_depth;
        let l = &mut self.lanes[lane];
        latch_events(&mut l.event_queue, &mut l.stats, depth, events)
    }

    /// Execute one global time step for the lanes listed in `active`
    /// (strictly ascending lane indices), writing lane `active[i]`'s
    /// emitted spikes into `outs[i]` (cleared first).
    ///
    /// In ideal-analog mode (unless `force_per_event_dispatch`) all active
    /// lanes share one CSR walk: the merged ascending stream of distinct
    /// events is dispatched once per event, depositing into every carrying
    /// lane — the module-docs invariants keep per-lane outputs and stats
    /// bit-identical to sequential execution. Otherwise each lane is
    /// stepped through the sequential engine itself (state swap).
    pub fn step_lanes_into(&mut self, active: &[usize], outs: &mut [Vec<u32>]) {
        assert_eq!(active.len(), outs.len(), "one output buffer per active lane");
        debug_assert!(active.windows(2).all(|w| w[0] < w[1]));
        let shared = self.is_ideal() && !self.force_per_event_dispatch;
        if !shared {
            for (out, &lane) in outs.iter_mut().zip(active) {
                self.step_lane_sequential(lane, out);
            }
            return;
        }

        let m = self.image.num_engines;
        let n = self.caps_per_engine;
        let scale = self.image.scale;
        let num_rounds = self.image.rounds.len();
        let skip = self.sweep_skip;
        let dense = self.force_dense_sweep;
        let beta = self.lif.beta;
        let th = self.lif.v_threshold;
        let q_reset = self.lif.v_reset;

        // Take the lanes out so the image-side fields can be borrowed
        // immutably while lane state is mutated.
        let mut lanes = std::mem::take(&mut self.lanes);
        let image = Arc::clone(&self.image);
        let rows_index = &self.rows_index;
        let row_entries = &self.row_entries;
        let residents_sorted = &self.residents_sorted;
        let sweep_cost = &self.sweep_cost;
        let mac_count = &mut self.mac_count;

        // Coalesce every active lane's queue into ascending (src, mult)
        // runs once; the runs are replayed per round exactly like the
        // sequential queue.
        for &li in active {
            let lane = &mut lanes[li];
            let q = &mut lane.event_queue;
            if q.len() > 1 && !q.windows(2).all(|w| w[0] <= w[1]) {
                q.sort_unstable();
            }
            lane.runs.clear();
            let mut i = 0usize;
            while i < q.len() {
                let src = q[i];
                let mut c = 1usize;
                while i + c < q.len() && q[i + c] == src {
                    c += 1;
                }
                lane.runs.push((src, c as u32));
                i += c;
            }
        }
        for out in outs.iter_mut() {
            out.clear();
        }

        let nl = active.len();
        let lane_cycles = &mut self.lane_cycles_scratch;
        lane_cycles.clear();
        lane_cycles.resize(nl, 0);
        let lane_rows = &mut self.lane_rows_scratch;
        lane_rows.clear();
        lane_rows.resize(nl, 0);
        let pos = &mut self.lane_pos_scratch;
        pos.clear();
        pos.resize(nl, 0);

        for round_idx in 0..num_rounds {
            let round = &image.rounds[round_idx];
            let residents = &residents_sorted[round_idx];
            let ridx = &rows_index[round_idx];
            let ents = &row_entries[round_idx];
            if num_rounds > 1 {
                // Capacitor reassignment: every lane reloads its own
                // parked state (charge transfer is per-lane, the image
                // walk is not).
                let reload = (residents.len() as u64).div_ceil(m as u64);
                for c in lane_cycles.iter_mut() {
                    *c += reload;
                }
            }

            // Merged dispatch: ascending distinct sources across lanes,
            // one MEM_E2A lookup + row-slice fetch per source.
            pos.fill(0);
            loop {
                let mut src = u32::MAX;
                for (ai, &li) in active.iter().enumerate() {
                    if let Some(&(s, _)) = lanes[li].runs.get(pos[ai]) {
                        src = src.min(s);
                    }
                }
                if src == u32::MAX {
                    break;
                }
                let s = src as usize;
                let (row_count, entries) = if s < round.e2a.len() && round.e2a[s].count > 0
                {
                    let e2a = round.e2a[s];
                    let lo = ridx[e2a.start as usize] as usize;
                    let hi = ridx[(e2a.start + e2a.count) as usize] as usize;
                    (e2a.count as u64, &ents[lo..hi])
                } else {
                    (0u64, &ents[0..0])
                };
                for (ai, &li) in active.iter().enumerate() {
                    let lane = &mut lanes[li];
                    let Some(&(ls, mult)) = lane.runs.get(pos[ai]) else {
                        continue;
                    };
                    if ls != src {
                        continue;
                    }
                    pos[ai] += 1;
                    let mult_u = mult as u64;
                    // Identical per-event accounting to the sequential
                    // dispatch: the controller pops each event (×mult).
                    lane.stats.events_dispatched += mult_u;
                    lane_cycles[ai] += mult_u;
                    if row_count == 0 {
                        continue;
                    }
                    lane_cycles[ai] += mult_u * row_count;
                    lane_rows[ai] += mult_u * row_count;
                    lane.stats.sn_rows_read += mult_u * row_count;
                    lane.stats.macs += mult_u * entries.len() as u64;
                    lane.stats.integrations += mult_u * entries.len() as u64;
                    let st = &mut lane.state[round_idx];
                    for &(j, virt, w) in entries {
                        let slot = j as usize * n + virt as usize;
                        st.acc[slot] += w as i32 * mult as i32;
                        st.dirty[slot] = true;
                        mac_count[j as usize] += mult_u;
                    }
                }
            }

            // End-of-step sweep, per lane. Residents outer so the shared
            // (slot, dst) list is read once; each lane's spikes come out
            // in the same dst order as sequentially.
            for &li in active.iter() {
                lanes[li].stats.fire_ops += residents.len() as u64;
            }
            for &(slot, dst) in residents {
                let slot = slot as usize;
                for (ai, &li) in active.iter().enumerate() {
                    let lane = &mut lanes[li];
                    let st = &mut lane.state[round_idx];
                    if !dense && !st.dirty[slot] {
                        continue; // provably a no-op (quiescent fixed point)
                    }
                    let v = beta * st.mem[slot] + st.acc[slot] as f32 * scale;
                    st.acc[slot] = 0;
                    st.err[slot] = 0.0;
                    if v >= th {
                        outs[ai].push(dst);
                        st.mem[slot] = q_reset;
                        lane.stats.spikes_out += 1;
                        st.dirty[slot] = !skip;
                    } else {
                        st.mem[slot] = v;
                        st.dirty[slot] = !(skip && v == q_reset);
                    }
                }
            }
            for c in lane_cycles.iter_mut() {
                *c += sweep_cost[round_idx];
            }
        }

        // Flush the batched per-engine MAC accounting (core-level: energy
        // is attributed to the silicon, not to lanes).
        for (j, &cnt) in mac_count.iter().enumerate() {
            if cnt > 0 {
                self.syns[j].macs += cnt;
                self.syns[j].energy += cnt as f64 * self.syns[j].energy_per_mac;
            }
        }
        mac_count.fill(0);

        for (ai, &li) in active.iter().enumerate() {
            let lane = &mut lanes[li];
            lane.event_queue.clear();
            lane.stats.cycles += lane_cycles[ai];
            if lane.stats.cycles_per_step.len() < STEP_SERIES_CAP {
                lane.stats.cycles_per_step.push(lane_cycles[ai]);
                lane.stats.sn_rows_touched_per_step.push(lane_rows[ai]);
            }
            let out = &mut outs[ai];
            if num_rounds > 1 && !out.windows(2).all(|w| w[0] <= w[1]) {
                out.sort_unstable();
            }
        }
        self.lanes = lanes;
    }

    /// Step one lane through the *sequential* engine by swapping its state
    /// into the core — the exact `step_into` code path, bit-identical by
    /// construction. Used for non-ideal analog mode and the
    /// `force_per_event_dispatch` differential knob.
    fn step_lane_sequential(&mut self, lane: usize, out: &mut Vec<u32>) {
        let mut l = std::mem::take(&mut self.lanes[lane]);
        std::mem::swap(&mut self.state, &mut l.state);
        std::mem::swap(&mut self.event_queue, &mut l.event_queue);
        std::mem::swap(&mut self.stats, &mut l.stats);
        self.step_into(out);
        std::mem::swap(&mut self.state, &mut l.state);
        std::mem::swap(&mut self.event_queue, &mut l.event_queue);
        std::mem::swap(&mut self.stats, &mut l.stats);
        self.lanes[lane] = l;
    }

    /// Fold every lane's accumulated *scalar* statistics into the
    /// core-level [`Self::stats`] and reset the lanes' own counters.
    /// Downstream consumers — the energy report, the CLI's merged
    /// shutdown chips — read only `stats`, so without this a lane-served
    /// workload would be invisible to them. Per-lane attribution is
    /// collapsed; call it at the end of a chip's service life (the
    /// coordinator's workers fold before handing their chips back).
    /// [`Self::analog_energy`] is unchanged by folding (it already sums
    /// both).
    ///
    /// The per-step series (`cycles_per_step`, `sn_rows_touched_per_step`)
    /// are **dropped**, not concatenated: each lane's series is its own
    /// timeline, and splicing them onto the core's would fabricate a
    /// step-by-step history that never happened (and break the figure
    /// consumers the series exist for). Capture [`Self::lane_stats`]
    /// before folding if per-lane series are needed.
    pub fn fold_lane_stats(&mut self) {
        for lane in self.lanes.iter_mut() {
            let s = std::mem::take(&mut lane.stats);
            self.stats.cycles += s.cycles;
            self.stats.events_dispatched += s.events_dispatched;
            self.stats.sn_rows_read += s.sn_rows_read;
            self.stats.macs += s.macs;
            self.stats.integrations += s.integrations;
            self.stats.fire_ops += s.fire_ops;
            self.stats.spikes_out += s.spikes_out;
            self.stats.peak_event_queue =
                self.stats.peak_event_queue.max(s.peak_event_queue);
            self.stats.dropped_events += s.dropped_events;
        }
    }

    /// Debug/test introspection: `(mem, acc, dirty)` per slot of one round
    /// of the *sequential* state (the dirty-slot invariant property tests).
    pub fn slot_states(&self, round: usize) -> Vec<(f32, i32, bool)> {
        let st = &self.state[round];
        (0..st.mem.len()).map(|i| (st.mem[i], st.acc[i], st.dirty[i])).collect()
    }

    /// Debug/test introspection: `(mem, acc, dirty)` per slot of one round
    /// of lane `lane`'s state.
    pub fn lane_slot_states(&self, lane: usize, round: usize) -> Vec<(f32, i32, bool)> {
        let st = &self.lanes[lane].state[round];
        (0..st.mem.len()).map(|i| (st.mem[i], st.acc[i], st.dirty[i])).collect()
    }

    /// Whether the quiescent-fixed-point sweep skip is enabled (module
    /// docs §activity-tracked sweep).
    pub fn sweep_skip_enabled(&self) -> bool {
        self.sweep_skip
    }

    /// Total analog energy consumed so far (J): A-SYN MACs plus A-NEURON
    /// integrate and sweep operations at the paper's per-op energy. Lane
    /// executions contribute through both terms (MAC energy accumulates in
    /// the shared A-SYN accounts; neuron ops live in the per-lane stats).
    pub fn analog_energy(&self) -> f64 {
        let mac_energy: f64 = self.syns.iter().map(|s| s.energy).sum();
        let mut neuron_ops = self.stats.integrations + self.stats.fire_ops;
        for lane in &self.lanes {
            neuron_ops += lane.stats.integrations + lane.stats.fire_ops;
        }
        mac_energy + neuron_ops as f64 * self.analog.neuron_energy_per_op
    }

    /// MEM_S&N rows present in the image, across rounds.
    pub fn image_sn_rows(&self) -> usize {
        self.image.rounds.iter().map(|r| r.sn_rows.len()).sum()
    }

    /// Weight SRAM bytes used.
    pub fn weight_bytes(&self) -> usize {
        self.image.weight_mem.len()
    }

    /// A-SYN MAC energy constant (J) — exposed for the energy model.
    pub fn mac_energy(&self) -> f64 {
        self.syns[0].energy_per_mac
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::mapping::{distill, map_layer, Strategy};
    use crate::snn::{reference_forward, LifParams, QuantLayer, QuantNetwork, SpikeTrain};
    use crate::util::rng::Rng;

    fn small_cfg(m: usize, n: usize) -> AcceleratorConfig {
        let mut c = AcceleratorConfig::accel1();
        c.a_neurons_per_core = m;
        c.a_syns_per_core = m;
        c.virtual_per_a_neuron = n;
        c
    }

    fn build_core(layer: &QuantLayer, cfg: &AcceleratorConfig, ideal: bool) -> NeuraCore {
        let mp = map_layer(layer, cfg, Strategy::IlpFlow).unwrap();
        mp.validate(layer, cfg).unwrap();
        let img = distill(layer, &mp, cfg).unwrap();
        let analog = if ideal { AnalogParams::ideal() } else { AnalogParams::paper() };
        let mut rng = Rng::new(99);
        NeuraCore::new(0, img, layer.lif, &analog, cfg, &mut rng).unwrap()
    }

    fn run_core(core: &mut NeuraCore, input: &SpikeTrain) -> SpikeTrain {
        let mut out = SpikeTrain::new(core.out_dim(), input.timesteps());
        for t in 0..input.timesteps() {
            core.push_events(&input.spikes[t]);
            out.spikes[t] = core.step();
        }
        out
    }

    fn random_layer(in_dim: usize, out_dim: usize, sparsity: f64, seed: u64) -> QuantLayer {
        let mut rng = Rng::new(seed);
        let mut w = vec![0i8; in_dim * out_dim];
        for x in w.iter_mut() {
            if !rng.bernoulli(sparsity) {
                *x = rng.range_inclusive(-127, 127) as i8;
            }
        }
        QuantLayer::new(
            in_dim,
            out_dim,
            w,
            0.02,
            LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.0 },
        )
        .unwrap()
    }

    fn random_input(dim: usize, t: usize, rate: f64, seed: u64) -> SpikeTrain {
        let mut rng = Rng::new(seed);
        let mut st = SpikeTrain::new(dim, t);
        for step in st.spikes.iter_mut() {
            for i in 0..dim {
                if rng.bernoulli(rate) {
                    step.push(i as u32);
                }
            }
        }
        st
    }

    /// The core in ideal-analog mode must match the reference bit-exactly.
    #[test]
    fn core_matches_reference_single_round() {
        let layer = random_layer(30, 12, 0.4, 1);
        let cfg = small_cfg(4, 4); // capacity 16 ≥ 12: single round
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 12 };
        let input = random_input(30, 12, 0.15, 2);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "ideal core ≠ reference");
        assert!(core.stats.macs > 0);
        assert!(core.stats.cycles > 0);
    }

    /// Multi-round mapping (more neurons than capacitors) must also match.
    #[test]
    fn core_matches_reference_multi_round() {
        let layer = random_layer(20, 30, 0.5, 3);
        let cfg = small_cfg(3, 4); // capacity 12 < 30: ≥3 rounds
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 10 };
        let input = random_input(20, 10, 0.2, 4);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        assert!(core.rounds() >= 3);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "multi-round ≠ reference");
    }

    /// Property: ideal equivalence holds across many random instances.
    #[test]
    fn prop_ideal_equivalence() {
        crate::util::prop::check_n("core-ref-equivalence", 20, |rng| {
            let in_dim = 5 + rng.below(30);
            let out_dim = 3 + rng.below(25);
            let m = 2 + rng.below(4);
            let n = 1 + rng.below(5);
            let layer = random_layer(in_dim, out_dim, 0.3 + rng.f64() * 0.5, rng.next_u64());
            let cfg = small_cfg(m, n);
            let t = 4 + rng.below(8);
            let input = random_input(in_dim, t, 0.1 + rng.f64() * 0.3, rng.next_u64());
            let net = QuantNetwork { name: "p".into(), layers: vec![layer.clone()], timesteps: t };
            let golden = reference_forward(&net, &input).map_err(|e| e.to_string())?;
            let mut core = build_core(&layer, &cfg, true);
            let out = run_core(&mut core, &input);
            if out.spikes != golden.output().spikes {
                return Err(format!(
                    "divergence: m={m} n={n} in={in_dim} out={out_dim} t={t}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn mismatch_only_mode_close_to_reference() {
        // C2C mismatch alone (no rail clamp, no injection, no droop) must
        // perturb spike counts by only a few percent.
        let layer = random_layer(40, 16, 0.4, 5);
        let cfg = small_cfg(4, 4);
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 20 };
        let input = random_input(40, 20, 0.15, 6);
        let golden = reference_forward(&net, &input).unwrap();
        let mut analog = AnalogParams::ideal();
        analog.c2c_mismatch_sigma = 0.002;
        let mp = map_layer(&layer, &cfg, Strategy::IlpFlow).unwrap();
        let img = distill(&layer, &mp, &cfg).unwrap();
        let mut rng = Rng::new(99);
        let mut core = NeuraCore::new(0, img, layer.lif, &analog, &cfg, &mut rng).unwrap();
        let out = run_core(&mut core, &input);
        let g = golden.output().total_spikes() as f64;
        let o = out.total_spikes() as f64;
        assert!(
            (o - g).abs() <= (0.10 * g).max(2.0),
            "mismatch-only spikes {o} too far from golden {g}"
        );
    }

    #[test]
    fn paper_analog_mode_same_order_as_reference() {
        // Full non-ideal mode adds the supply-rail clamp, which the
        // rail-less reference cannot reproduce: membranes that would drift
        // deeply negative recover sooner, so the count shifts — but must
        // stay within the same order (factor ~2) and the core must still
        // be live.
        let layer = random_layer(40, 16, 0.4, 5);
        let cfg = small_cfg(4, 4);
        let net = QuantNetwork { name: "t".into(), layers: vec![layer.clone()], timesteps: 20 };
        let input = random_input(40, 20, 0.15, 6);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, false);
        let out = run_core(&mut core, &input);
        let g = golden.output().total_spikes() as f64;
        let o = out.total_spikes() as f64;
        assert!(o > 0.0);
        assert!(o <= 2.5 * g && o >= g / 2.5, "non-ideal spikes {o} vs golden {g}");
    }

    #[test]
    fn cycles_scale_with_activity() {
        let layer = random_layer(30, 10, 0.3, 7);
        let cfg = small_cfg(5, 2);
        let quiet = random_input(30, 10, 0.02, 8);
        let busy = random_input(30, 10, 0.5, 9);
        let mut c1 = build_core(&layer, &cfg, true);
        run_core(&mut c1, &quiet);
        let mut c2 = build_core(&layer, &cfg, true);
        run_core(&mut c2, &busy);
        assert!(
            c2.stats.cycles > c1.stats.cycles,
            "busy {} ≤ quiet {}",
            c2.stats.cycles,
            c1.stats.cycles
        );
        assert!(c2.stats.sn_rows_read > c1.stats.sn_rows_read);
    }

    #[test]
    fn event_memory_overflow_drops() {
        let layer = random_layer(100, 4, 0.5, 10);
        let mut cfg = small_cfg(2, 2);
        cfg.event_mem_depth = 8;
        let mut core = build_core(&layer, &cfg, true);
        let events: Vec<u32> = (0..20).collect();
        let dropped = core.push_events(&events);
        assert_eq!(dropped, 12);
        assert_eq!(core.stats.dropped_events, 12);
        assert_eq!(core.stats.peak_event_queue, 8);
    }

    #[test]
    fn reset_membranes_clears_state_keeps_stats() {
        let layer = random_layer(20, 8, 0.3, 11);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, true);
        let input = random_input(20, 6, 0.3, 12);
        run_core(&mut core, &input);
        let cycles = core.stats.cycles;
        assert!(cycles > 0);
        core.reset_membranes();
        assert_eq!(core.stats.cycles, cycles, "stats must survive reset");
        // State is cleared: a silent step emits nothing.
        let out = core.step();
        assert!(out.is_empty());
    }

    #[test]
    fn per_step_series_lengths_match() {
        let layer = random_layer(20, 8, 0.3, 13);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, true);
        let input = random_input(20, 7, 0.2, 14);
        run_core(&mut core, &input);
        // 7 event steps + 1 silent step from reset test? No: exactly 7.
        assert_eq!(core.stats.cycles_per_step.len(), 7);
        assert_eq!(core.stats.sn_rows_touched_per_step.len(), 7);
        assert_eq!(
            core.stats.cycles_per_step.iter().sum::<u64>(),
            core.stats.cycles
        );
    }

    #[test]
    fn analog_energy_accumulates() {
        let layer = random_layer(20, 8, 0.3, 15);
        let cfg = small_cfg(2, 4);
        let mut core = build_core(&layer, &cfg, false);
        assert_eq!(core.analog_energy(), 0.0);
        let input = random_input(20, 5, 0.3, 16);
        run_core(&mut core, &input);
        assert!(core.analog_energy() > 0.0);
        let expected = (core.stats.integrations + core.stats.fire_ops) as f64
            * AnalogParams::paper().neuron_energy_per_op
            + core.stats.macs as f64 * core.mac_energy();
        assert!((core.analog_energy() - expected).abs() / expected < 1e-9);
    }

    /// Differential regression: the activity-tracked sweep and event
    /// coalescing must leave every [`CoreStats`] counter AND the output
    /// spikes bit-identical to the dense/per-event execution path
    /// (`force_dense_sweep` / `force_per_event_dispatch` replicate the
    /// pre-perf-pass behaviour).
    #[test]
    fn sparse_execution_stats_match_dense_execution() {
        for (seed, m, n) in [(21u64, 4usize, 4usize), (22, 3, 5), (23, 5, 2)] {
            let layer = random_layer(40, 24, 0.4, seed);
            let cfg = small_cfg(m, n);
            let input = random_input(40, 15, 0.12, seed + 100);

            let mut fast = build_core(&layer, &cfg, true);
            let out_fast = run_core(&mut fast, &input);

            let mut dense = build_core(&layer, &cfg, true);
            dense.force_dense_sweep = true;
            dense.force_per_event_dispatch = true;
            let out_dense = run_core(&mut dense, &input);

            assert_eq!(out_fast.spikes, out_dense.spikes, "seed {seed}: outputs diverge");
            let (f, d) = (&fast.stats, &dense.stats);
            assert_eq!(f.cycles, d.cycles, "seed {seed}: cycles");
            assert_eq!(f.fire_ops, d.fire_ops, "seed {seed}: fire_ops");
            assert_eq!(f.macs, d.macs, "seed {seed}: macs");
            assert_eq!(f.sn_rows_read, d.sn_rows_read, "seed {seed}: sn_rows_read");
            assert_eq!(f.events_dispatched, d.events_dispatched, "seed {seed}");
            assert_eq!(f.integrations, d.integrations, "seed {seed}");
            assert_eq!(f.spikes_out, d.spikes_out, "seed {seed}");
            assert_eq!(f.cycles_per_step, d.cycles_per_step, "seed {seed}");
            assert_eq!(
                f.sn_rows_touched_per_step, d.sn_rows_touched_per_step,
                "seed {seed}"
            );
            assert!(
                (fast.analog_energy() - dense.analog_energy()).abs() <= f64::EPSILON,
                "seed {seed}: energy accounting diverges"
            );
        }
    }

    /// Duplicate MEM_E entries (same source spiking "twice" in a step, as a
    /// caller may inject) must behave identically coalesced or not —
    /// including the ×multiplicity cycle/row/MAC accounting.
    #[test]
    fn coalesced_duplicates_match_per_event_dispatch() {
        let layer = random_layer(20, 12, 0.3, 31);
        let cfg = small_cfg(4, 3);
        // Deliberately unsorted with duplicates: exercises the sort +
        // run-length path.
        let events: Vec<u32> = vec![5, 1, 5, 5, 2, 1, 9, 9];

        let mut fast = build_core(&layer, &cfg, true);
        let mut dense = build_core(&layer, &cfg, true);
        dense.force_per_event_dispatch = true;

        for _ in 0..4 {
            fast.push_events(&events);
            dense.push_events(&events);
            assert_eq!(fast.step(), dense.step(), "outputs diverge");
        }
        assert_eq!(fast.stats.cycles, dense.stats.cycles);
        assert_eq!(fast.stats.events_dispatched, dense.stats.events_dispatched);
        assert_eq!(fast.stats.sn_rows_read, dense.stats.sn_rows_read);
        assert_eq!(fast.stats.macs, dense.stats.macs);
        assert_eq!(fast.stats.integrations, dense.stats.integrations);
        assert_eq!(fast.stats.events_dispatched as usize, 8 * 4 * fast.rounds());
    }

    /// A non-zero `v_reset` whose leak is not a fixed point must disable
    /// sweep skipping (every slot permanently dirty) and still match the
    /// reference bit-exactly.
    #[test]
    fn nonzero_v_reset_disables_skip_and_matches_reference() {
        let lif = LifParams { beta: 0.9, v_threshold: 1.0, v_reset: 0.25 };
        assert!(!quiescent_fixed_point(&lif, &AnalogParams::ideal()));
        let mut rng = Rng::new(41);
        let mut w = vec![0i8; 30 * 12];
        for x in w.iter_mut() {
            if !rng.bernoulli(0.4) {
                *x = rng.range_inclusive(-127, 127) as i8;
            }
        }
        let layer = QuantLayer::new(30, 12, w, 0.02, lif).unwrap();
        let cfg = small_cfg(4, 4);
        let net =
            QuantNetwork { name: "vr".into(), layers: vec![layer.clone()], timesteps: 12 };
        let input = random_input(30, 12, 0.15, 42);
        let golden = reference_forward(&net, &input).unwrap();
        let mut core = build_core(&layer, &cfg, true);
        let out = run_core(&mut core, &input);
        assert_eq!(out.spikes, golden.output().spikes, "v_reset≠0 core ≠ reference");
    }

    /// `beta == 1, v_reset == 0` IS a fixed point (no leak decay) — the
    /// skip stays valid.
    #[test]
    fn quiescence_check_accepts_no_leak() {
        let lif = LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 0.0 };
        assert!(quiescent_fixed_point(&lif, &AnalogParams::ideal()));
        // A reset value at/above threshold would fire forever: not quiescent.
        let hot = LifParams { beta: 1.0, v_threshold: 1.0, v_reset: 1.0 };
        assert!(!quiescent_fixed_point(&hot, &AnalogParams::ideal()));
    }

    /// step_into reuses the caller's buffer and matches step().
    #[test]
    fn step_into_matches_step() {
        let layer = random_layer(20, 8, 0.3, 51);
        let cfg = small_cfg(2, 4);
        let input = random_input(20, 6, 0.3, 52);
        let mut a = build_core(&layer, &cfg, true);
        let mut b = build_core(&layer, &cfg, true);
        let mut buf = vec![99u32; 7]; // stale contents must be cleared
        for t in 0..input.timesteps() {
            a.push_events(&input.spikes[t]);
            b.push_events(&input.spikes[t]);
            b.step_into(&mut buf);
            assert_eq!(a.step(), buf, "step {t}");
        }
        assert_eq!(a.stats.cycles, b.stats.cycles);
    }

    /// Drive a batch through the lane API at core level: one push + step
    /// per global time step, lanes shorter than the longest input going
    /// inactive once exhausted.
    fn run_core_lanes(core: &mut NeuraCore, inputs: &[SpikeTrain]) -> Vec<SpikeTrain> {
        let b = inputs.len();
        core.ensure_lanes(b);
        core.reset_lanes();
        let t_max = inputs.iter().map(|s| s.timesteps()).max().unwrap_or(0);
        let mut outs: Vec<SpikeTrain> = inputs
            .iter()
            .map(|s| SpikeTrain::new(core.out_dim(), s.timesteps()))
            .collect();
        let mut bufs: Vec<Vec<u32>> = Vec::new();
        for t in 0..t_max {
            let active: Vec<usize> =
                (0..b).filter(|&i| t < inputs[i].timesteps()).collect();
            bufs.resize_with(active.len(), Vec::new);
            for &i in &active {
                core.push_events_lane(i, &inputs[i].spikes[t]);
            }
            core.step_lanes_into(&active, &mut bufs);
            for (ai, &i) in active.iter().enumerate() {
                outs[i].spikes[t] = std::mem::take(&mut bufs[ai]);
            }
        }
        outs
    }

    /// The shared-CSR lane walk must be bit-identical — outputs AND every
    /// per-lane CoreStats counter — to fresh sequential cores.
    #[test]
    fn lanes_match_sequential_per_core() {
        let layer = random_layer(30, 18, 0.4, 61);
        let cfg = small_cfg(3, 4); // capacity 12 < 18: multi-round
        let inputs: Vec<SpikeTrain> = (0..4)
            .map(|i| random_input(30, 10, 0.05 + 0.1 * i as f64, 70 + i as u64))
            .collect();

        let mut laned = build_core(&layer, &cfg, true);
        let lane_outs = run_core_lanes(&mut laned, &inputs);

        for (i, input) in inputs.iter().enumerate() {
            let mut seq = build_core(&layer, &cfg, true);
            let seq_out = run_core(&mut seq, input);
            assert_eq!(lane_outs[i].spikes, seq_out.spikes, "lane {i}: outputs");
            assert_eq!(laned.lane_stats(i), &seq.stats, "lane {i}: stats");
        }
        // Core-level sequential stats stay untouched by lane execution.
        assert_eq!(laned.stats, CoreStats::default());
    }

    /// Duplicate events in a lane's queue take the coalesced path; the
    /// ×multiplicity accounting must match per-event dispatch.
    #[test]
    fn lane_duplicates_match_force_per_event() {
        let layer = random_layer(20, 12, 0.3, 62);
        let cfg = small_cfg(4, 3);
        let events: Vec<u32> = vec![5, 1, 5, 5, 2, 1, 9, 9];
        let mut input = SpikeTrain::new(20, 4);
        for t in 0..4 {
            input.spikes[t] = events.clone();
        }
        let inputs = vec![input.clone(), input];

        let mut fast = build_core(&layer, &cfg, true);
        let fast_outs = run_core_lanes(&mut fast, &inputs);
        let mut slow = build_core(&layer, &cfg, true);
        slow.force_per_event_dispatch = true;
        let slow_outs = run_core_lanes(&mut slow, &inputs);

        for i in 0..2 {
            assert_eq!(fast_outs[i].spikes, slow_outs[i].spikes, "lane {i}");
            assert_eq!(fast.lane_stats(i), slow.lane_stats(i), "lane {i}: stats");
        }
    }

    /// Non-ideal analog mode routes lanes through the sequential engine —
    /// still bit-identical to per-lane sequential cores (same mismatch
    /// seeds).
    #[test]
    fn nonideal_lanes_fall_back_to_sequential_path() {
        let layer = random_layer(25, 10, 0.4, 63);
        let cfg = small_cfg(5, 2);
        let inputs: Vec<SpikeTrain> =
            (0..3).map(|i| random_input(25, 8, 0.2, 80 + i as u64)).collect();

        let mut laned = build_core(&layer, &cfg, false);
        let lane_outs = run_core_lanes(&mut laned, &inputs);
        for (i, input) in inputs.iter().enumerate() {
            let mut seq = build_core(&layer, &cfg, false);
            let seq_out = run_core(&mut seq, input);
            assert_eq!(lane_outs[i].spikes, seq_out.spikes, "lane {i}: outputs");
            assert_eq!(laned.lane_stats(i), &seq.stats, "lane {i}: stats");
        }
    }

    /// ensure_lanes keeps existing lane state, reset_lanes clears state but
    /// keeps stats, and lane overflow accounting is per-lane.
    #[test]
    fn lane_lifecycle_and_overflow() {
        let layer = random_layer(40, 8, 0.4, 64);
        let mut cfg = small_cfg(2, 4);
        cfg.event_mem_depth = 8;
        let mut core = build_core(&layer, &cfg, true);
        core.ensure_lanes(2);
        assert_eq!(core.num_lanes(), 2);
        let events: Vec<u32> = (0..20).collect();
        let dropped = core.push_events_lane(1, &events);
        assert_eq!(dropped, 12);
        assert_eq!(core.lane_stats(1).dropped_events, 12);
        assert_eq!(core.lane_stats(1).peak_event_queue, 8);
        assert_eq!(core.lane_stats(0).dropped_events, 0);
        let cycles_before = {
            let mut bufs = vec![Vec::new(), Vec::new()];
            core.step_lanes_into(&[0, 1], &mut bufs);
            core.lane_stats(1).cycles
        };
        assert!(cycles_before > 0);
        core.reset_lanes();
        assert_eq!(core.lane_stats(1).cycles, cycles_before, "stats survive reset");
        // Growing keeps old lanes, adds quiescent ones.
        core.ensure_lanes(3);
        assert_eq!(core.num_lanes(), 3);
        assert_eq!(core.lane_stats(1).cycles, cycles_before);
        assert_eq!(core.lane_stats(2).cycles, 0);
    }

    /// fold_lane_stats moves every counter into core stats, zeroes the
    /// lanes, and leaves the energy total bit-identical.
    #[test]
    fn fold_lane_stats_moves_totals_to_core() {
        let layer = random_layer(30, 12, 0.4, 65);
        let cfg = small_cfg(4, 3);
        let inputs: Vec<SpikeTrain> =
            (0..3).map(|i| random_input(30, 6, 0.2, 90 + i as u64)).collect();
        let mut core = build_core(&layer, &cfg, true);
        run_core_lanes(&mut core, &inputs);
        let energy_before = core.analog_energy();
        let expected_macs: u64 = (0..3).map(|i| core.lane_stats(i).macs).sum();
        let expected_cycles: u64 = (0..3).map(|i| core.lane_stats(i).cycles).sum();
        assert!(expected_macs > 0);
        core.fold_lane_stats();
        assert_eq!(core.stats.macs, expected_macs);
        assert_eq!(core.stats.cycles, expected_cycles);
        for i in 0..3 {
            assert_eq!(core.lane_stats(i), &CoreStats::default());
        }
        assert_eq!(core.analog_energy(), energy_before, "folding changed energy");
    }

    #[test]
    fn engine_count_mismatch_rejected() {
        let layer = random_layer(10, 4, 0.3, 17);
        let cfg4 = small_cfg(4, 2);
        let mp = map_layer(&layer, &cfg4, Strategy::Greedy).unwrap();
        let img = distill(&layer, &mp, &cfg4).unwrap();
        let cfg2 = small_cfg(2, 2);
        let mut rng = Rng::new(1);
        assert!(NeuraCore::new(
            0,
            img,
            layer.lif,
            &AnalogParams::ideal(),
            &cfg2,
            &mut rng
        )
        .is_err());
    }
}
