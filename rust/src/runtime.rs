//! PJRT runtime: load and execute the JAX-lowered golden model from rust.
//!
//! The python compile path (`python/compile/aot.py`) lowers the quantized,
//! Pallas-fused inference function to HLO **text** (the interchange format
//! xla_extension 0.5.1 accepts); this module wraps the `xla` crate to
//! compile that text on the PJRT CPU client and execute it from the request
//! path: feed an event raster, get class spike counts back.
//!
//! The coordinator uses it as the *golden model* against which the
//! cycle-accurate simulator is cross-checked, exactly as the paper checks
//! its RTL against the SNNTorch model (Algorithm 1, step 4: "mimic the
//! Python-level spiking neural network behaviour").
//!
//! **Feature gating:** the `xla` crate is not vendored in the hermetic
//! build, so the real implementation only compiles with the off-by-default
//! `pjrt` cargo feature (see Cargo.toml). Without it this module exposes
//! the same API surface as a stub whose entry points return a descriptive
//! error — callers (`tests/e2e_golden.rs`, `examples/*_e2e.rs`, the
//! `--golden` CLI flag) detect the situation and skip the cross-check.

use std::path::Path;

use anyhow::Result;
#[cfg(not(feature = "pjrt"))]
use anyhow::bail;
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};

use crate::snn::SpikeTrain;

/// Whether this build carries a real PJRT runtime. `false` means
/// [`cpu_client`] / [`GoldenModel::load`] will always error and golden
/// cross-checks should be skipped, not failed.
pub const fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// The PJRT CPU client handle (stub type when built without `pjrt`).
#[cfg(not(feature = "pjrt"))]
pub struct CpuClient {
    _private: (),
}

#[cfg(feature = "pjrt")]
pub type CpuClient = xla::PjRtClient;

/// A compiled golden model ready to execute.
pub struct GoldenModel {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Event raster shape the executable expects: (timesteps, input_dim).
    pub timesteps: usize,
    pub input_dim: usize,
    /// Output classes.
    pub num_classes: usize,
}

#[cfg(feature = "pjrt")]
impl GoldenModel {
    /// Load `<name>.hlo.txt`, compile on the PJRT CPU client.
    ///
    /// `timesteps`/`input_dim` must match the shape the model was lowered
    /// with (read them from `artifacts/manifest.json` or the weights file).
    pub fn load(
        client: &CpuClient,
        hlo_path: impl AsRef<Path>,
        timesteps: usize,
        input_dim: usize,
        num_classes: usize,
    ) -> Result<Self> {
        let path = hlo_path.as_ref();
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Self { exe, timesteps, input_dim, num_classes })
    }

    /// Execute on a dense f32 event raster `[timesteps * input_dim]`
    /// (row-major). Returns the per-class spike counts.
    pub fn run_raster(&self, raster: &[f32]) -> Result<Vec<f32>> {
        if raster.len() != self.timesteps * self.input_dim {
            bail!(
                "raster has {} entries, expected {}×{}",
                raster.len(),
                self.timesteps,
                self.input_dim
            );
        }
        let input = xla::Literal::vec1(raster)
            .reshape(&[self.timesteps as i64, self.input_dim as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (counts, out_spikes).
        let elems = result.to_tuple()?;
        if elems.is_empty() {
            bail!("executable returned empty tuple");
        }
        let counts = elems[0].to_vec::<f32>()?;
        if counts.len() != self.num_classes {
            bail!("expected {} classes, got {}", self.num_classes, counts.len());
        }
        Ok(counts)
    }
}

#[cfg(not(feature = "pjrt"))]
impl GoldenModel {
    /// Stub: always errors — this build has no PJRT runtime.
    pub fn load(
        _client: &CpuClient,
        hlo_path: impl AsRef<Path>,
        _timesteps: usize,
        _input_dim: usize,
        _num_classes: usize,
    ) -> Result<Self> {
        bail!(
            "cannot load {}: built without the `pjrt` cargo feature (see Cargo.toml)",
            hlo_path.as_ref().display()
        );
    }

    /// Stub: always errors — this build has no PJRT runtime.
    pub fn run_raster(&self, _raster: &[f32]) -> Result<Vec<f32>> {
        bail!("built without the `pjrt` cargo feature");
    }
}

impl GoldenModel {
    /// Execute on a [`SpikeTrain`], densifying it first.
    pub fn run(&self, input: &SpikeTrain) -> Result<Vec<f32>> {
        if input.num_neurons != self.input_dim || input.timesteps() != self.timesteps {
            anyhow::bail!(
                "spike train is {}×{}, model expects {}×{}",
                input.timesteps(),
                input.num_neurons,
                self.timesteps,
                self.input_dim
            );
        }
        let mut raster = vec![0.0f32; self.timesteps * self.input_dim];
        for (t, step) in input.spikes.iter().enumerate() {
            for &n in step {
                raster[t * self.input_dim + n as usize] = 1.0;
            }
        }
        self.run_raster(&raster)
    }

    /// Predicted class = argmax of counts (ties toward lower index,
    /// matching [`SpikeTrain::argmax_class`]).
    pub fn predict(&self, input: &SpikeTrain) -> Result<usize> {
        let counts = self.run(input)?;
        let mut best = 0usize;
        for (i, &v) in counts.iter().enumerate() {
            if v > counts[best] {
                best = i;
            }
        }
        Ok(best)
    }
}

/// Create the PJRT CPU client (one per process). Errors when the crate was
/// built without the `pjrt` feature.
pub fn cpu_client() -> Result<CpuClient> {
    #[cfg(feature = "pjrt")]
    {
        xla::PjRtClient::cpu().context("creating PJRT CPU client")
    }
    #[cfg(not(feature = "pjrt"))]
    {
        bail!("PJRT support not compiled in: enable the `pjrt` cargo feature");
    }
}

/// Locate the artifacts directory: `$MENAGE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("MENAGE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full PJRT integration tests live in tests/e2e_golden.rs (they need
    // `make artifacts` and a `pjrt` build). Here: pure-rust helpers only.

    #[test]
    fn artifacts_dir_default() {
        if std::env::var("MENAGE_ARTIFACTS").is_err() {
            assert_eq!(artifacts_dir(), std::path::PathBuf::from("artifacts"));
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_errors_are_descriptive() {
        assert!(!pjrt_available());
        let err = cpu_client().err().unwrap().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
