//! Minimal JSON reader/writer.
//!
//! Used for run metadata, trace export (Figures 6–7 series), and the weight
//! manifest written by `python/compile/aot.py`. Supports the full JSON value
//! model; numbers are f64 (adequate — the bulk tensor data travels in the
//! binary [`super::tensorfile`] format, not JSON).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    /// Object field access with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// `obj.get(key)` that tolerates absence.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

/// Compact serialization (`.to_string()` comes via the blanket
/// `ToString`; an inherent `to_string` would shadow it and trip clippy's
/// `inherent_to_string` in the CI lint gate).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut arr = Vec::new();
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    self.ws();
                    arr.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(arr));
                        }
                        c => bail!("expected ',' or ']' at offset {}, got {:?}", self.i, c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut obj = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(obj));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.expect(b':')?;
                    self.ws();
                    obj.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(obj));
                        }
                        c => bail!("expected ',' or '}}' at offset {}, got {:?}", self.i, c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs unsupported (not needed here);
                            // map lone surrogates to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("invalid escape at offset {}", self.i),
                    }
                }
                c => {
                    // Re-decode UTF-8 multi-byte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = s
            .parse()
            .map_err(|_| anyhow!("invalid number {s:?} at offset {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1.0);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_deep() {
        let v = Json::obj(vec![
            ("name", "accel₁ — ünïcode".into()),
            ("vals", Json::arr_f64(&[1.5, -2.0, 1e-9])),
            ("n", 42usize.into()),
            ("ok", true.into()),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn accessors_error_cleanly() {
        let v = Json::parse("{\"k\": 1.5}").unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("k").unwrap().as_str().is_err());
        assert!(v.get("k").unwrap().as_usize().is_err()); // fractional
        assert!(v.opt("missing").is_none());
    }
}
