//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via SplitMix64 — the standard, well-tested small
//! PRNG pair. Every stochastic component in the crate (dataset generators,
//! analog mismatch models, property tests) takes an explicit seed so runs
//! are exactly reproducible; nothing in the crate touches OS entropy.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step — used for seeding and as a one-shot hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// New generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream (e.g. per-layer, per-image).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (n > 0), Lemire rejection-free variant.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        // Avoid ln(0).
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Poisson-distributed count (Knuth for small λ, normal approx. above 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            return self.normal(lambda, lambda.sqrt()).round().max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential inter-arrival time with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let s: u64 = (0..n).map(|_| r.poisson(4.0)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
        // Large-lambda path.
        let s: u64 = (0..n).map(|_| r.poisson(100.0)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - 100.0).abs() < 0.5, "mean={mean}");
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(9);
        let hits = (0..50_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 50_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(2);
        for _ in 0..100 {
            let s = r.sample_indices(50, 10);
            assert_eq!(s.len(), 10);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 50));
        }
        assert_eq!(r.sample_indices(5, 5), vec![0, 1, 2, 3, 4]);
        assert!(r.sample_indices(5, 0).is_empty());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(12);
        let mut f1 = r.fork(0);
        let mut f2 = r.fork(1);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
