//! `.mtz` — the MENAGE tensor container.
//!
//! A trivially parseable binary format used to move quantized weights,
//! scales and recorded spike tensors from the python compile path
//! (`python/compile/aot.py` writes it with plain `struct.pack`) into rust.
//! Little-endian throughout.
//!
//! ```text
//! magic   b"MTZ1"
//! u32     tensor count
//! per tensor:
//!   u32         name length, then name bytes (utf-8)
//!   u8          dtype  (0 = f32, 1 = i8, 2 = i32, 3 = u8)
//!   u8          ndim
//!   u64 × ndim  dims
//!   bytes       data (row-major, dtype-sized elements)
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

const MAGIC: &[u8; 4] = b"MTZ1";

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I8 = 1,
    I32 = 2,
    U8 = 3,
}

impl DType {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            3 => DType::U8,
            _ => bail!("unknown dtype tag {v}"),
        })
    }

    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }
}

/// A dense row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I8 { dims: Vec<usize>, data: Vec<i8> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    U8 { dims: Vec<usize>, data: Vec<u8> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. }
            | Tensor::I8 { dims, .. }
            | Tensor::I32 { dims, .. }
            | Tensor::U8 { dims, .. } => dims,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I8 { .. } => DType::I8,
            Tensor::I32 { .. } => DType::I32,
            Tensor::U8 { .. } => DType::U8,
        }
    }

    pub fn len(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is {:?}, expected f32", self.dtype())),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            Tensor::I8 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is {:?}, expected i8", self.dtype())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is {:?}, expected i32", self.dtype())),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            Tensor::U8 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is {:?}, expected u8", self.dtype())),
        }
    }
}

/// A named collection of tensors (the file's content).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, Tensor>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("tensor {name:?} not in file (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(t.dtype() as u8);
            out.push(t.dims().len() as u8);
            for &d in t.dims() {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for v in data {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Tensor::I8 { data, .. } => {
                    out.extend(data.iter().map(|&v| v as u8));
                }
                Tensor::I32 { data, .. } => {
                    for v in data {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Tensor::U8 { data, .. } => out.extend_from_slice(data),
            }
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut r = Reader { b, i: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            bail!("bad magic {magic:?}");
        }
        let count = r.u32()? as usize;
        let mut tf = TensorFile::new();
        for _ in 0..count {
            let name_len = r.u32()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)?.to_string();
            let dtype = DType::from_u8(r.u8()?)?;
            let ndim = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u64()? as usize);
            }
            let n: usize = dims.iter().product();
            let raw = r.take(n * dtype.size())?;
            let t = match dtype {
                DType::F32 => Tensor::F32 {
                    dims,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                },
                DType::I32 => Tensor::I32 {
                    dims,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                },
                DType::I8 => Tensor::I8 { dims, data: raw.iter().map(|&v| v as i8).collect() },
                DType::U8 => Tensor::U8 { dims, data: raw.to_vec() },
            };
            tf.insert(name, t);
        }
        if r.i != b.len() {
            bail!("trailing bytes after tensor data");
        }
        Ok(tf)
    }

    /// Write to a file path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Read from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut b = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut b)?;
        Self::from_bytes(&b).with_context(|| format!("parsing {}", path.display()))
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated file: wanted {n} bytes at offset {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TensorFile {
        let mut tf = TensorFile::new();
        tf.insert(
            "w0",
            Tensor::I8 { dims: vec![2, 3], data: vec![1, -2, 3, -4, 5, -128] },
        );
        tf.insert("scale", Tensor::F32 { dims: vec![1], data: vec![0.03125] });
        tf.insert("counts", Tensor::I32 { dims: vec![4], data: vec![0, -1, i32::MAX, 7] });
        tf.insert("mask", Tensor::U8 { dims: vec![2, 2], data: vec![0, 1, 1, 0] });
        tf
    }

    #[test]
    fn roundtrip_bytes() {
        let tf = sample();
        let b = tf.to_bytes();
        let back = TensorFile::from_bytes(&b).unwrap();
        assert_eq!(back, tf);
    }

    #[test]
    fn roundtrip_disk() {
        let tf = sample();
        let dir = std::env::temp_dir().join(format!("mtz_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.mtz");
        tf.save(&p).unwrap();
        let back = TensorFile::load(&p).unwrap();
        assert_eq!(back, tf);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_error_lists_names() {
        let tf = sample();
        let e = tf.get("nope").unwrap_err().to_string();
        assert!(e.contains("nope") && e.contains("w0"), "{e}");
    }

    #[test]
    fn rejects_corruption() {
        let tf = sample();
        let mut b = tf.to_bytes();
        b[0] = b'X'; // magic
        assert!(TensorFile::from_bytes(&b).is_err());
        let b = tf.to_bytes();
        assert!(TensorFile::from_bytes(&b[..b.len() - 1]).is_err()); // truncated
        let mut b2 = tf.to_bytes();
        b2.push(0); // trailing
        assert!(TensorFile::from_bytes(&b2).is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let tf = sample();
        assert!(tf.get("w0").unwrap().as_f32().is_err());
        assert!(tf.get("w0").unwrap().as_i8().is_ok());
        assert!(tf.get("scale").unwrap().as_f32().is_ok());
        assert!(tf.get("counts").unwrap().as_i32().is_ok());
        assert!(tf.get("mask").unwrap().as_u8().is_ok());
    }

    #[test]
    fn empty_and_zero_dim_tensors() {
        let mut tf = TensorFile::new();
        tf.insert("e", Tensor::F32 { dims: vec![0, 5], data: vec![] });
        let back = TensorFile::from_bytes(&tf.to_bytes()).unwrap();
        assert_eq!(back.get("e").unwrap().len(), 0);
        assert!(back.get("e").unwrap().is_empty());
    }
}
