//! Dependency-free utility substrate.
//!
//! The build environment vendors only the `xla` crate's dependency tree, so
//! everything a "normal" project would pull from crates.io lives here:
//!
//! * [`rng`] — deterministic PRNG (SplitMix64 / xoshiro256**) with the
//!   distributions the dataset generators and noise models need.
//! * [`json`] — minimal JSON reader/writer used for config echo, trace
//!   export, and small metadata files.
//! * [`tensorfile`] — the binary tensor container (`.mtz`) that carries
//!   quantized weights and recorded spike tensors from the python compile
//!   path into the rust runtime.
//! * [`prop`] — a tiny seeded property-testing driver (stand-in for
//!   proptest): N random cases per property, failing seed reported.
//! * [`stats`] — streaming summary statistics used by benches and the
//!   energy model.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tensorfile;
