//! Streaming summary statistics and quantile estimation.
//!
//! Shared by the bench harness (latency distributions), the energy model
//! (per-event energy aggregation) and the trace module (Figures 6–7 memory
//! utilization series).

/// Online mean/variance/min/max via Welford's algorithm.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another summary into this one (parallel aggregation).
    pub fn merge(&mut self, o: &Summary) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        let mean = self.mean + d * o.n as f64 / n as f64;
        self.m2 += o.m2 + d * d * self.n as f64 * o.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.sum += o.sum;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Exact quantiles over a retained sample vector (fine at bench scale).
#[derive(Debug, Clone, Default)]
pub struct Quantiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Quantiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Quantile `q ∈ [0,1]` with linear interpolation.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let f = pos - lo as f64;
            self.xs[lo] * (1.0 - f) + self.xs[hi] * f
        }
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.sum(), 10.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut whole = Summary::new();
        for (i, &x) in xs.iter().enumerate() {
            whole.add(x);
            if i % 2 == 0 {
                a.add(x)
            } else {
                b.add(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn merge_with_empty() {
        let mut a = Summary::new();
        a.add(5.0);
        let b = Summary::new();
        a.merge(&b);
        assert_eq!(a.count(), 1);
        let mut e = Summary::new();
        e.merge(&a);
        assert_eq!(e.count(), 1);
        assert_eq!(e.mean(), 5.0);
    }

    #[test]
    fn quantiles() {
        let mut q = Quantiles::new();
        for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
            q.add(x);
        }
        assert_eq!(q.median(), 3.0);
        assert_eq!(q.quantile(0.0), 1.0);
        assert_eq!(q.quantile(1.0), 5.0);
        assert_eq!(q.quantile(0.25), 2.0);
        assert!((q.quantile(0.9) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn quantiles_empty_and_single() {
        let mut q = Quantiles::new();
        assert!(q.quantile(0.5).is_nan());
        q.add(7.0);
        assert_eq!(q.median(), 7.0);
    }
}
