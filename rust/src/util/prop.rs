//! Tiny seeded property-testing driver (offline stand-in for proptest).
//!
//! A property is a closure over a [`Rng`](super::rng::Rng); the driver runs
//! it across `cases` independent deterministic seeds and panics with the
//! failing seed on the first violation, so failures reproduce with
//! `check_seed(name, SEED, prop)`.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: u64 = 128;

/// Run `prop` across `cases` seeds derived from the property name.
///
/// Panics (test failure) with the offending seed when `prop` panics or
/// returns an `Err`-like `Result<(), String>`.
pub fn check_n<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = name_hash(name);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0xA24B_AED4_963E_E407));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with util::prop::check_seed({name:?}, {seed:#x}, ...)"
            );
        }
    }
}

/// Run `prop` with [`DEFAULT_CASES`] cases.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_n(name, DEFAULT_CASES, prop);
}

/// Re-run a single failing seed (debugging helper).
pub fn check_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property {name:?} failed (seed {seed:#x}): {msg}");
    }
}

/// FNV-1a over the property name — stable across runs and platforms.
fn name_hash(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_n("always-true", 17, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property \"always-false\" failed")]
    fn failing_property_panics_with_seed() {
        check_n("always-false", 4, |_rng| Err("nope".to_string()));
    }

    #[test]
    fn seeds_are_deterministic() {
        let mut first: Vec<u64> = vec![];
        check_n("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        check_n("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
        assert_eq!(first.len(), 5);
        // Different cases see different streams.
        assert!(first.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn prop_assert_macro() {
        check_n("macro", 8, |rng| {
            let v = rng.below(10);
            prop_assert!(v < 10, "v={v} out of range");
            Ok(())
        });
    }
}
